"""Merge-free adapter-pool serving (DESIGN.md §5, docs/SERVING.md).

The contract under test, layer by layer:

  * `ops.overlay_matmul` / `ops.delta_matmul` (lax AND kernel backends)
    compose a per-slot sparse delta into the base matmul bitwise-equal
    to `ref.delta_matmul` (dense merge-then-matmul per slot), with
    all-sentinel slots riding the base weights untouched;
  * `deltas.PoolLayout.pack` stores MERGED resident values: composing a
    packed entry into the base reproduces `DeltaMerger` bit for bit —
    replace, add, and fp16 (format v2) artifacts alike;
  * the `AdapterPool` never evicts a page an in-flight request holds
    (the KVPool refs==1-only invariant), survives an admit/evict/
    complete fuzz against a host-side model of its bookkeeping, and
    refuses wrong-base / wrong-geometry artifacts;
  * end to end: a PagedEngine decode batch MIXING adapters per slot
    through the pool is token-identical to merge-on-load AdapterStore
    serving at greedy AND sampled temperatures, with speculation on or
    off, and under eviction churn in a pool sized for one adapter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lift import LiftConfig, get_by_path, make_plan
from repro.data.synthetic import VOCAB_SIZE
from repro.deltas import DeltaArtifact, DeltaMismatchError, PoolLayout
from repro.deltas.format import make_manifest, num_stack, tree_hash
from repro.deltas.merge import DeltaMerger
from repro.deltas.pool_layout import SENTINEL_IDX
from repro.kernels import ops, ref
from repro.models import ModelConfig, build_model
from repro.serving import AdapterStore, Request, ServingConfig
from repro.serving.kvpool import AdapterPool, PagedEngine, pool_overlay

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=max(VOCAB_SIZE, 97))
ENTRIES = 512


def _model_params(seed=0):
    model = build_model(CFG)
    return model, model.init(jax.random.PRNGKey(seed))


def _prompts(n, seed=3, lo=3, hi=33):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _plan_meta(model, density=0.05):
    plan = make_plan(model.spec(), LiftConfig(density=density, min_dim=16))
    return {p: {"shape": list(t.shape), "stack": list(t.stack),
                "rows": t.rows, "cols": t.cols, "k": t.k,
                "dtype": "float32"} for p, t in sorted(plan.items())}


def _synthetic_adapter(base_params, meta, seed, *, mode="replace",
                       base_hash=None, value_dtype=None):
    """A delta artifact perturbing the base at random planned indices —
    real extract geometry without the training loop."""
    rng = np.random.default_rng(seed)
    meta = {p: dict(m) for p, m in meta.items()}
    tensors = {}
    for path, m in meta.items():
        ns, k = num_stack(m), m["k"]
        size = m["rows"] * m["cols"]
        idx = np.stack([np.sort(rng.choice(size, k, replace=False))
                        for _ in range(ns)]).astype(np.int32)
        noise = rng.normal(scale=0.05, size=(ns, k)).astype(np.float32)
        if mode == "replace":
            base = np.asarray(get_by_path(base_params, path),
                              np.float32).reshape(ns, size)
            val = np.take_along_axis(base, idx, 1) + noise
        else:
            val = noise
        if value_dtype is not None:
            val = val.astype(np.dtype(value_dtype))
            m["value_dtype"] = value_dtype
        tensors[path] = {"idx": idx, "val": val.astype(val.dtype)}
    return DeltaArtifact(
        manifest=make_manifest(
            mode=mode,
            base_hash=base_hash or tree_hash(base_params),
            selection=None, tensors_meta=meta, step=0),
        tensors=tensors)


# ------------------------------------------------------- op-level bitwise
@pytest.mark.parametrize("backend", ["lax", "kernel"])
def test_overlay_matmul_bitwise_vs_ref(backend):
    """Both delta-matmul backends match the dense merge-then-matmul
    oracle bitwise, and an all-sentinel slot rides the base weights."""
    rng = np.random.default_rng(0)
    d, f, B, k = 32, 48, 3, 24
    x = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d, f)).astype(np.float32))
    idx = np.stack([np.sort(rng.choice(d * f, k, replace=False))
                    for _ in range(B)]).astype(np.int32)
    idx[1] = SENTINEL_IDX                   # base-only slot
    val = rng.normal(size=(B, k)).astype(np.float32)
    idxj, valj = jnp.asarray(idx), jnp.asarray(val)

    want = ref.delta_matmul(x, w, idxj, valj)
    got = ops.delta_matmul(x, w, idxj, valj, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    ov = {"idx": idxj, "val": valj}
    got2 = ops.overlay_matmul(x, w, ov, backend=backend)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))
    # the sentinel slot is exactly the base matmul row
    np.testing.assert_array_equal(np.asarray(got[1]),
                                  np.asarray(x @ w)[1])
    # decode shape (B, 1, d) and overlay None
    got3 = ops.overlay_matmul(x[:, None, :], w, ov, backend=backend)
    np.testing.assert_array_equal(np.asarray(got3[:, 0]), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(ops.overlay_matmul(x, w, None)), np.asarray(x @ w))


# ------------------------------------------------ layout resident values
@pytest.mark.parametrize("mode, value_dtype", [
    ("replace", None), ("add", None), ("replace", "float16"),
])
def test_pool_layout_resident_values_match_merger(mode, value_dtype):
    """Scattering a packed adapter's resident (idx, val) entries into
    the base reproduces the DeltaMerger merged tree bit for bit —
    replace ships values, add gathers base[idx] + val in fp32, fp16
    values upcast exactly (format v2)."""
    rng = np.random.default_rng(1)
    meta = {
        "a/w": {"shape": [2, 16, 24], "stack": [2], "rows": 16,
                "cols": 24, "k": 10, "dtype": "float32"},
        "b/w": {"shape": [32, 20], "stack": [], "rows": 32,
                "cols": 20, "k": 7, "dtype": "float32"},
    }
    base = {p: rng.normal(size=m["shape"]).astype(np.float32)
            for p, m in meta.items()}
    art = _synthetic_adapter(base, meta, seed=2, mode=mode,
                             value_dtype=value_dtype)
    merged = DeltaMerger(art.manifest["tensors"],
                         backend="ref").merge(base, art)

    lay = PoolLayout(art.manifest["tensors"], entries_per_page=64)
    idx_pages, val_pages = lay.pack(base, art)
    flat_idx = idx_pages.reshape(-1)
    flat_val = val_pages.reshape(-1)
    for p, (off, ns, k) in lay.slices().items():
        m = meta[p]
        size = m["rows"] * m["cols"]
        ii = jnp.asarray(flat_idx[off:off + ns * k].reshape(ns, k))
        vv = jnp.asarray(flat_val[off:off + ns * k].reshape(ns, k))
        b2 = jnp.asarray(base[p]).reshape(ns, size)
        # resident values are pre-merged: composing is always "replace"
        got = ref.sparse_scatter_merge(b2, ii, vv, mode="replace")
        np.testing.assert_array_equal(
            np.asarray(got).reshape(m["shape"]),
            np.asarray(get_by_path(merged, p)), err_msg=p)
    # tail slots beyond the last tensor pad with the sentinel
    assert (flat_idx[lay.total_entries:] == int(SENTINEL_IDX)).all()


def test_pool_overlay_gather_shapes():
    """pool_overlay turns (P, E) pages + a (B, ppa) page table into the
    (L, B, k) overlay leaves the scanned forward consumes; the all-zero
    row gathers the trash page's sentinels."""
    model, params = _model_params()
    meta = _plan_meta(model)
    apool = AdapterPool(params, num_pages=17, entries_per_page=ENTRIES)
    apool.register("a", _synthetic_adapter(params, meta, seed=3))
    pages = apool.acquire("a")
    ppa = apool.layout.pages_per_adapter
    apt = np.zeros((2, ppa), np.int32)
    apt[0] = pages                           # slot 0: adapter, slot 1: base
    ov = pool_overlay(apool.idx_pages, apool.val_pages,
                      jnp.asarray(apt), apool.layout.slices(),
                      CFG.num_layers)
    assert set(ov) == {"attn", "mlp"}
    assert set(ov["attn"]) == {"wq", "wk", "wv", "wo"}
    assert set(ov["mlp"]) == {"up", "gate", "down"}
    for grp in ov.values():
        for nm, leaf in grp.items():
            k = leaf["idx"].shape[-1]
            assert leaf["idx"].shape == (CFG.num_layers, 2, k)
            assert leaf["val"].shape == (CFG.num_layers, 2, k)
            # base slot: every entry is the sentinel no-op
            assert (np.asarray(leaf["idx"])[:, 1] == int(SENTINEL_IDX)).all()
    apool.release(pages)


# --------------------------------------------------- residency invariants
def test_pool_never_evicts_referenced_pages():
    """A pool at capacity must make a new adapter WAIT (acquire -> None)
    rather than evict pages held by in-flight requests; releasing the
    holders makes the same acquire succeed via LRU eviction."""
    model, params = _model_params()
    meta = _plan_meta(model)
    # size for exactly ONE adapter
    probe = PoolLayout(meta, entries_per_page=ENTRIES)
    apool = AdapterPool(params, num_pages=probe.pages_per_adapter + 1,
                        entries_per_page=ENTRIES)
    apool.register("a", _synthetic_adapter(params, meta, seed=4))
    apool.register("b", _synthetic_adapter(params, meta, seed=5))
    held = apool.acquire("a")
    assert held and len(held) == apool.layout.pages_per_adapter
    assert apool.resident_adapters() == 1
    assert apool.acquire("b") is None        # never evicts referenced
    assert apool.resident_adapters() == 1    # rollback left "a" intact
    # a second in-flight reference to the SAME adapter is free (cache hit)
    held2 = apool.acquire("a")
    assert held2 == held
    assert apool.uploads == apool.layout.pages_per_adapter
    apool.release(held)
    assert apool.acquire("b") is None        # held2 still pins the pages
    apool.release(held2)
    got_b = apool.acquire("b")               # idle "a" LRU-evicts now
    assert got_b is not None
    assert apool.pool.evictions == apool.layout.pages_per_adapter
    assert apool.resident_adapters() == 1
    apool.release(got_b)


def test_pool_admit_evict_complete_fuzz():
    """Randomized acquire/release against a host-side model of the
    bookkeeping: refcounts = holders + cache ref, held adapters' pages
    stay disjoint and device-resident, acquire fails only when the
    unreferenced-cached + free pages cannot fund an adapter."""
    model, params = _model_params()
    meta = _plan_meta(model, density=0.01)
    probe = PoolLayout(meta, entries_per_page=ENTRIES)
    ppa = probe.pages_per_adapter
    n_adapters, capacity = 6, 3              # room for 3 of 6 adapters
    apool = AdapterPool(params, num_pages=1 + capacity * ppa,
                        entries_per_page=ENTRIES)
    packed = {}
    for i in range(n_adapters):
        aid = f"ad{i}"
        apool.register(aid, _synthetic_adapter(params, meta,
                                               seed=100 + i))
        packed[aid] = apool._packed[aid]
    rng = np.random.default_rng(6)
    held: list = []                          # (adapter_id, pages)
    for step in range(200):
        if held and rng.random() < 0.4:
            aid, pages = held.pop(rng.integers(len(held)))
            apool.release(pages)
        else:
            aid = f"ad{rng.integers(n_adapters)}"
            pages = apool.acquire(aid)
            if pages is None:
                # exhaustion must be REAL: pages pinned by holders alone
                # already crowd out one more adapter
                pinned = {p for _, pg in held for p in pg}
                assert (apool.num_pages - 1 - len(pinned)) < ppa or \
                    len({a for a, _ in held}) >= capacity
                continue
            held.append((aid, pages))
        # ---- invariants after every op
        holders: dict = {}
        for a, pg in held:
            for p in pg:
                holders[p] = holders.get(p, 0) + 1
        cached = {apool.pool._cached[c] for c in apool.pool.cached_chains()}
        for p in range(1, apool.num_pages):
            want = holders.get(p, 0) + (1 if p in cached else 0)
            assert apool.pool.refs[p] == want, (step, p)
        by_adapter: dict = {}
        for a, pg in held:
            if a in by_adapter:
                assert by_adapter[a] == pg   # same pages per adapter
            by_adapter[a] = pg
        pages_of = {a: set(pg) for a, pg in by_adapter.items()}
        for a, sa in pages_of.items():
            for b, sb in pages_of.items():
                if a != b:
                    assert not (sa & sb), (a, b)
        assert apool.resident_adapters() <= capacity
    # content spot-check: every held adapter's device pages equal its
    # packed images
    idx_host = np.asarray(apool.idx_pages)
    val_host = np.asarray(apool.val_pages)
    for aid, pages in held:
        idx_img, val_img = packed[aid]
        for i, p in enumerate(pages):
            np.testing.assert_array_equal(idx_host[p], idx_img[i])
            np.testing.assert_array_equal(val_host[p], val_img[i])
    for _, pages in held:
        apool.release(pages)


# ---------------------------------------------------------------- refusals
def test_register_refuses_wrong_base_and_geometry():
    model, params = _model_params()
    meta = _plan_meta(model)
    apool = AdapterPool(params, num_pages=17, entries_per_page=ENTRIES)
    # wrong base hash
    with pytest.raises(DeltaMismatchError, match="base"):
        apool.register("x", _synthetic_adapter(params, meta, seed=7,
                                               base_hash="f" * 64))
    # geometry drift: same paths, different k
    apool.register("a", _synthetic_adapter(params, meta, seed=8))
    drifted = {p: dict(m, k=m["k"] + 8) for p, m in meta.items()}
    with pytest.raises(DeltaMismatchError, match="geometry|plan"):
        apool.register("y", _synthetic_adapter(params, drifted, seed=9))
    # a pool too small for even one adapter refuses at layout fix time
    tiny = AdapterPool(params, num_pages=2, entries_per_page=ENTRIES)
    with pytest.raises(ValueError, match="num_pages"):
        tiny.register("a", _synthetic_adapter(params, meta, seed=8))


def test_engine_pool_refusals():
    model, params = _model_params()
    meta = _plan_meta(model)
    apool = AdapterPool(params, num_pages=17, entries_per_page=ENTRIES)
    apool.register("a", _synthetic_adapter(params, meta, seed=10))
    cfg = ServingConfig(batch_slots=2, max_len=64, eos_id=2,
                            page_size=8, num_pages=24)
    # store and pool together
    with pytest.raises(ValueError, match="not both"):
        PagedEngine(model, params, cfg, adapters=AdapterStore(params),
                    adapter_pool=apool)
    # layout-less pool (nothing registered)
    empty = AdapterPool(params, num_pages=17, entries_per_page=ENTRIES)
    with pytest.raises(ValueError, match="no layout"):
        PagedEngine(model, params, cfg, adapter_pool=empty)
    # a plan covering a non-overlayable tensor (vocab-axis embed)
    bad_meta = dict(meta)
    bad_meta["embed/w"] = {"shape": [CFG.vocab_size, 64], "stack": [],
                           "rows": CFG.vocab_size, "cols": 64, "k": 8,
                           "dtype": "float32"}
    bad_pool = AdapterPool(params, num_pages=33, entries_per_page=ENTRIES,
                           validate=False)
    bad_pool.layout = PoolLayout(bad_meta, entries_per_page=ENTRIES)
    with pytest.raises(ValueError, match="embed"):
        PagedEngine(model, params, cfg, adapter_pool=bad_pool)
    # non-dense family
    moe_cfg = ModelConfig(family="moe", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=max(VOCAB_SIZE, 97),
                          num_experts=4, num_experts_per_tok=2)
    moe = build_model(moe_cfg)
    moe_params = moe.init(jax.random.PRNGKey(0))
    moe_pool = AdapterPool(moe_params, num_pages=17,
                           entries_per_page=ENTRIES, validate=False)
    moe_pool.layout = apool.layout
    with pytest.raises(ValueError, match="dense"):
        PagedEngine(moe, moe_params, cfg, adapter_pool=moe_pool)
    # unregistered adapter fails fast at submit
    eng = PagedEngine(model, params, cfg, adapter_pool=apool)
    with pytest.raises(KeyError):
        eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                           adapter_id="ghost"))


# ------------------------------------------------------------- end to end
def _serve_paged(model, params, prompts, ids, temps, *, apool=None,
                 store=None, num_pages=9999, speculate=0, max_new=8):
    eng = PagedEngine(model, params, ServingConfig(
        batch_slots=3, max_len=64, eos_id=2, page_size=8,
        num_pages=min(num_pages, 40), speculate=speculate,
        draft_source="ngram"), adapters=store, adapter_pool=apool)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           temperature=temps[i], adapter_id=ids[i]))
    mixed = 0
    while eng.sched.has_work():
        eng.step()
        live = {s.req.adapter_id for s in eng.sched.seqs
                if s is not None and s.phase == "decode"
                and s.req.adapter_id is not None}
        mixed = max(mixed, len(live))
    assert len(eng.done) == len(prompts)
    assert not any(r.error for r in eng.done)
    return {r.uid: tuple(r.out_tokens) for r in eng.done}, mixed, eng


def test_pool_serving_token_identical_to_merge_on_load():
    """The acceptance proof: a decode batch mixing two adapters and the
    base through the pool — greedy and sampled temperatures in one run —
    is token-identical to merge-on-load AdapterStore serving, with and
    without speculation, and the base weights never move."""
    model, params = _model_params()
    meta = _plan_meta(model)
    arts = {aid: _synthetic_adapter(params, meta, seed)
            for aid, seed in (("a", 11), ("b", 22))}
    apool = AdapterPool(params, num_pages=24, entries_per_page=ENTRIES)
    for aid, art in arts.items():
        apool.register(aid, art)
    store = AdapterStore(params)
    for aid, art in arts.items():
        store.load(aid, art)

    prompts = _prompts(6, seed=5)
    ids = ["a", "b", None, "a", "b", "a"]
    temps = [0.0, 0.8, 0.0, 0.7, 0.0, 0.9]
    got, mixed, eng = _serve_paged(model, params, prompts, ids, temps,
                                   apool=apool)
    want, _, _ = _serve_paged(model, params, prompts, ids, temps,
                              store=store)
    assert got == want
    assert mixed >= 2                        # the batch actually mixed
    assert eng.params is params              # base never replaced
    # speculation changes dispatch shape, never the streams
    spec, _, eng_s = _serve_paged(model, params, prompts, ids, temps,
                                  apool=apool, speculate=2)
    assert spec == want
    assert eng_s.decode_compilations == 1


def test_pool_eviction_churn_keeps_streams_identical():
    """A pool with room for ONE adapter serving a two-adapter workload:
    requests wait for pages, idle adapters are LRU-evicted and
    re-uploaded, and every token stream still matches the
    eviction-free run."""
    model, params = _model_params()
    meta = _plan_meta(model)
    arts = {aid: _synthetic_adapter(params, meta, seed)
            for aid, seed in (("a", 11), ("b", 22))}

    def pool(n_pages):
        ap = AdapterPool(params, num_pages=n_pages,
                         entries_per_page=ENTRIES)
        for aid, art in arts.items():
            ap.register(aid, art)
        return ap

    prompts = _prompts(4, seed=8)
    ids = ["a", "b", "a", "b"]
    temps = [0.0, 0.6, 0.0, 0.6]
    big = pool(24)
    want, _, _ = _serve_paged(model, params, prompts, ids, temps,
                              apool=big)
    ppa = big.layout.pages_per_adapter
    tight = pool(ppa + 1)
    got, _, eng = _serve_paged(model, params, prompts, ids, temps,
                               apool=tight)
    assert got == want
    assert tight.pool.evictions >= ppa       # churn actually happened
    assert tight.uploads > ppa * 2 - 1       # "a"/"b" re-uploaded
    assert eng.pool_stats()["resident_adapters"] <= 1
