"""Error-feedback top-k gradient compression contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.parallel.compression import (EFState, compress, compressed_psum,
                                        decompress, init_ef, wire_bytes)


def test_compress_decompress_topk_identity():
    g = jnp.asarray(np.random.default_rng(0).normal(size=256), jnp.float32)
    ef = init_ef(g)
    vals, idx, ef2 = compress(g, ef, ratio=0.1)
    rec = decompress(vals, idx, g.shape)
    # reconstructed entries are exactly the top-|.| entries of g
    top = np.argsort(-np.abs(np.asarray(g)))[:25]
    assert set(np.asarray(idx).tolist()) == set(top.tolist())
    np.testing.assert_allclose(np.asarray(rec)[top], np.asarray(g)[top],
                               rtol=1e-6)
    # error feedback holds the complement
    np.testing.assert_allclose(np.asarray(ef2.residual),
                               np.asarray(g - rec), atol=1e-6)


def test_error_feedback_recovers_constant_gradient():
    """With a constant gradient, sum of transmitted updates over T steps
    approaches T*g — nothing is permanently lost."""
    g = jnp.asarray(np.random.default_rng(1).normal(size=128), jnp.float32)
    ef = init_ef(g)
    acc = jnp.zeros_like(g)
    T = 50
    for _ in range(T):
        vals, idx, ef = compress(g, ef, ratio=0.05)
        acc = acc + decompress(vals, idx, g.shape)
    err = float(jnp.linalg.norm(acc - T * g) / jnp.linalg.norm(T * g))
    assert err < 0.2, err


@settings(max_examples=15, deadline=None)
@given(st.integers(16, 300), st.floats(0.02, 0.5), st.integers(0, 2 ** 12))
def test_prop_compression_is_contraction(n, ratio, seed):
    """||g+r - C(g+r)||^2 <= (1 - k/n) ||g+r||^2 (top-k contraction)."""
    g = jnp.asarray(np.random.default_rng(seed).normal(size=n), jnp.float32)
    ef = init_ef(g)
    vals, idx, ef2 = compress(g, ef, ratio)
    k = max(1, int(ratio * n))
    lhs = float(jnp.sum(ef2.residual ** 2))
    rhs = (1 - k / n) * float(jnp.sum(g ** 2))
    assert lhs <= rhs + 1e-5


def test_compressed_psum_single_device_semantics():
    """On a 1-device axis the compressed psum equals plain top-k apply."""
    from jax.sharding import Mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    g = jnp.asarray(np.random.default_rng(3).normal(size=64), jnp.float32)
    ef = init_ef(g)

    fn = shard_map(
        lambda gg, rr: compressed_psum(gg, EFState(rr), 0.25, "pod"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False)
    out, ef2 = fn(g, ef.residual)
    vals, idx, _ = compress(g, ef, 0.25)
    want = decompress(vals, idx, g.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_wire_bytes_model():
    w = wire_bytes(10_000_000, 0.01, pods=2)
    assert w["topk"] < w["dense_bf16"]
    assert 0.9 < w["saving"] <= 1.0
