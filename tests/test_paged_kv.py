"""PagedKV subsystem (DESIGN.md §5): the block-paged pool allocator, the
paged-attention kernel vs its dense reference, and the acceptance proof —
the continuous-batching paged engine is token-identical to the dense-cache
engine on mixed-length (and mixed-adapter, mixed-temperature) request
streams, under monolithic and chunked prefill, through page exhaustion
(preemption / stalling) and prefix-page sharing.

Speculative multi-token decode rides the same acceptance proof: for
every draft source (n-gram prompt-lookup, model self/garbage drafting),
any acceptance rate, and any temperature, the verified streams must stay
BITWISE identical to one-token decode — plus the multi-query verify
kernel vs its oracle, per-row bitwise equality against one-token decode,
the scheduler's N-token growth accounting, and the single-compiled-
program invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import ModelConfig, build_model
from repro.serving import Request, ServingConfig, make_engine
from repro.serving.kvpool import (KVPool, PagedEngine, PagedScheduler,
                                  TRASH_PAGE)
from repro.serving.oracle import DenseOracle

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)


@pytest.fixture(scope="module")
def model_params():
    model = build_model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(n, seed=3, lo=3, hi=40):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve_dense(model, params, prompts, *, temps=None, max_new=8,
                 slots=3, max_len=64, adapters=None, adapter_ids=None):
    eng = DenseOracle(model, params, ServingConfig(
        batch_slots=slots, max_len=max_len, eos_id=2), adapters=adapters)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           temperature=temps[i] if temps else 0.0,
                           adapter_id=adapter_ids[i] if adapter_ids
                           else None))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.uid: tuple(r.out_tokens) for r in done}


def _serve_paged(model, params, prompts, *, temps=None, max_new=8,
                 slots=3, max_len=64, page_size=8, num_pages=40,
                 adapters=None, adapter_ids=None, draft_model=None,
                 draft_params=None, **kw):
    eng = make_engine(model, params, ServingConfig(
        batch_slots=slots, max_len=max_len, eos_id=2, page_size=page_size,
        num_pages=num_pages, **kw), adapters=adapters,
        draft_model=draft_model, draft_params=draft_params)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           temperature=temps[i] if temps else 0.0,
                           adapter_id=adapter_ids[i] if adapter_ids
                           else None))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.uid: tuple(r.out_tokens) for r in done}, eng


# ------------------------------------------------------------ pool unit
def test_pool_alloc_release_refcount():
    pool = KVPool(num_pages=6, page_size=4)
    a = pool.alloc(3)
    assert a is not None and TRASH_PAGE not in a     # page 0 reserved
    assert pool.pages_in_use() == 3
    assert pool.alloc(3) is None                     # only 2 left
    b = pool.alloc(2)
    assert set(a) & set(b) == set()
    pool.retain(a[0])
    pool.release(a[0])
    assert pool.alloc(1) is None                     # still referenced
    pool.release(a[0])
    assert pool.alloc(1) == [a[0]]                   # refcount hit 0
    assert pool.peak_pages_in_use == 5


def test_pool_prefix_cache_refcounts_and_eviction():
    pool = KVPool(num_pages=5, page_size=4)
    pages = pool.alloc(3)
    pool.cache_put("c0", pages[0])                   # cache takes a ref
    pool.cache_put("c1", pages[1])
    for p in pages:
        pool.release(p)                              # request finished
    assert pool.pages_in_use() == 2                  # cached pages pinned
    got = pool.cache_get("c0")
    assert got == pages[0]
    # a full-pool alloc evicts only UNREFERENCED cached pages (c1), then
    # fails rather than stealing c0 (a live request holds it)
    assert pool.alloc(4) is None
    assert pool.evictions == 1
    assert pool.cache_get("c1") is None
    pool.release(got)
    assert pool.alloc(4) is not None                 # c0 evictable now
    assert pool.evictions == 2


def test_pool_rejects_degenerate_sizes():
    with pytest.raises(ValueError):
        KVPool(num_pages=1, page_size=4)
    with pytest.raises(ValueError):
        KVPool(num_pages=4, page_size=0)


# ------------------------------------------------------- kernel parity
def test_paged_attention_kernel_matches_ref():
    rng = np.random.default_rng(0)
    B, hkv, g, D, P, ps, nmax = 3, 2, 2, 16, 9, 4, 6
    q = jnp.asarray(rng.normal(size=(B, hkv, g, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([0, 9, 23], np.int32))
    want = ref.paged_attention(q.reshape(B, hkv * g, D), kp, vp, bt,
                               pos).reshape(B, hkv, g, D)
    for backend in ("kernel", "lax"):
        got = ops.paged_attention_decode(q, kp, vp, bt, pos,
                                         backend=backend, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6,
                                   err_msg=backend)


def test_paged_attention_kernel_bf16():
    rng = np.random.default_rng(1)
    B, hkv, g, D, P, ps, nmax = 2, 2, 4, 32, 7, 8, 4
    q = jnp.asarray(rng.normal(size=(B, hkv, g, D))).astype(jnp.bfloat16)
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D))).astype(jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D))).astype(jnp.bfloat16)
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([5, 30], np.int32))
    want = ref.paged_attention(
        q.astype(jnp.float32).reshape(B, hkv * g, D),
        kp.astype(jnp.float32), vp.astype(jnp.float32), bt, pos)
    got = ops.paged_attention_decode(q, kp, vp, bt, pos, backend="kernel",
                                     interpret=True)
    np.testing.assert_allclose(
        np.asarray(got.astype(jnp.float32).reshape(B, hkv * g, D)),
        np.asarray(want), rtol=3e-2, atol=3e-2)


# -------------------------------------------------- engine token identity
def test_paged_engine_token_identical_mixed_temperatures(model_params):
    """The acceptance proof at its strongest: mixed prompt lengths AND
    mixed temperatures, monolithic-prefill paged engine vs dense engine.
    Per-request rng + the bitwise-matching monolithic prefill/decode path
    make even sampled streams identical."""
    model, params = model_params
    prompts = _prompts(8)
    temps = [0.0, 0.8, 0.0, 1.2, 0.0, 0.5, 0.0, 0.9]
    want = _serve_dense(model, params, prompts, temps=temps)
    got, eng = _serve_paged(model, params, prompts, temps=temps)
    assert got == want
    st = eng.kv_stats()
    assert st["kv_bytes_ratio"] < 1.0      # bounded by live tokens...
    assert st["within_live_bound"]         # ...not slots x max_len


def test_chunked_prefill_token_identical_and_one_program(model_params):
    """Chunked prefill interleaves with decode and stays token-identical
    to both the dense engine and the monolithic paged engine — through
    ONE compiled prefill program (fixed chunk shape), not one per length
    bucket."""
    model, params = model_params
    prompts = _prompts(8, seed=11, lo=3, hi=60)
    want = _serve_dense(model, params, prompts, max_len=96)
    got, eng = _serve_paged(model, params, prompts, max_len=96,
                            num_pages=60, chunked_prefill=True,
                            prefill_chunk=16)
    assert got == want
    assert eng.prefill_chunks > len(prompts)     # long prompts chunked
    assert eng.prefill_compilations == 1         # one (C, mode) program


@pytest.mark.parametrize("family, kw", [
    ("moe", dict(num_experts=4, num_experts_per_tok=2)),
    ("hybrid", dict(num_heads=4, head_dim=32, shared_attn_period=2,
                    num_layers=4)),
])
def test_paged_engine_families_token_identical(family, kw):
    """MoE pages its KV with exact-length prefill (capacity dispatch is
    pad/chunk-sensitive); the zamba hybrid pages its shared-attention KV
    while the mamba backbone keeps fixed spliced recurrent state."""
    cfg = ModelConfig(family=family, d_model=64, num_kv_heads=2, d_ff=128,
                      vocab_size=97,
                      num_layers=kw.pop("num_layers", 2),
                      num_heads=kw.pop("num_heads", 4),
                      head_dim=kw.pop("head_dim", 16), **kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(5, seed=7)
    want = _serve_dense(model, params, prompts, slots=2, max_new=6)
    got, eng = _serve_paged(model, params, prompts, slots=2, max_new=6,
                            num_pages=30)
    assert got == want
    assert not eng._chunked and not eng.sched.prefix_cache  # gated off


def test_engine_refuses_degenerate_configs():
    """Unified-engine guardrails: rwkv6 + stall (recurrent state cannot
    survive a stall), hybrid + stall (same), and a sliding window that
    never slides inside the serving envelope."""
    rw = ModelConfig(family="rwkv6", num_layers=2, d_model=64, num_heads=2,
                     num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=97)
    model = build_model(rw)
    with pytest.raises(ValueError, match="stall"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    ServingConfig(exhaustion="stall"))
    swa = CFG.replace(sliding_window=32)
    model = build_model(swa)
    with pytest.raises(ValueError, match="window"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    ServingConfig(max_len=32))
    # hybrid + stall: a stalled slot's mamba state would advance on dummy
    # dispatch inputs — refused up front (preempt checkpoints + resumes)
    zam = ModelConfig(family="hybrid", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=97, shared_attn_period=2)
    model = build_model(zam)
    with pytest.raises(ValueError, match="stall"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    ServingConfig(exhaustion="stall"))


def test_mixed_adapter_stream_token_identical(model_params, tmp_path):
    """Mixed-adapter continuous batching through the pool: every request
    matches the dense engine serving the same adapters."""
    from test_serving_delta import _tiny_delta
    from repro.serving import AdapterStore
    model, base = model_params
    d1, _ = _tiny_delta(model, base, 11, tmp_path, "a")
    d2, _ = _tiny_delta(model, base, 22, tmp_path, "b")

    def store():
        s = AdapterStore(base, backend="kernel")
        s.load("a", d1)
        s.load("b", d2)
        return s

    prompts = _prompts(6, seed=5)
    ids = ["a", "b", None, "a", "b", None]
    want = _serve_dense(model, base, prompts, adapters=store(),
                        adapter_ids=ids)
    got, _ = _serve_paged(model, base, prompts, adapters=store(),
                          adapter_ids=ids)
    assert got == want


# ------------------------------------------------- exhaustion / eviction
def test_page_exhaustion_preempt_and_stall(model_params):
    """A pool far smaller than slots x max_len still completes every
    request with identical tokens: 'preempt' restarts the youngest
    sequence (per-request rng regenerates the same stream), 'stall'
    parks the growing sequence until pages free up."""
    model, params = model_params
    prompts = _prompts(6, seed=5, lo=20, hi=48)
    want = _serve_dense(model, params, prompts, max_new=10)
    roomy, _ = _serve_paged(model, params, prompts, max_new=10,
                            num_pages=60)
    assert roomy == want
    tight_p, ep = _serve_paged(model, params, prompts, max_new=10,
                               num_pages=10, exhaustion="preempt")
    assert tight_p == want
    assert ep.sched.preemptions > 0
    tight_s, es = _serve_paged(model, params, prompts, max_new=10,
                               num_pages=10, exhaustion="stall")
    assert tight_s == want
    assert es.sched.stalls > 0


def test_prefix_cache_reuse_and_eviction(model_params):
    """Shared-prefix requests reuse reference-counted prefix pages
    (token-identical, fewer prefill tokens computed); pool pressure
    evicts only unreferenced cached pages."""
    model, params = model_params
    rng = np.random.default_rng(9)
    pre_a = rng.integers(3, 90, size=24).astype(np.int32)
    pre_b = rng.integers(3, 90, size=24).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(3, 90,
                                                 size=6).astype(np.int32)])
               for pre in (pre_a, pre_a, pre_a, pre_b, pre_b)]
    want = _serve_dense(model, params, prompts, slots=2, max_new=6,
                        max_len=48)
    got, eng = _serve_paged(model, params, prompts, slots=2, max_new=6,
                            max_len=48, prefix_cache=True)
    assert got == want
    assert eng.sched.prefix_hits > 0
    # under pressure the cache gives unreferenced pages back (prefix B
    # evicts prefix A's cached pages) instead of starving admissions
    tight, et = _serve_paged(model, params, prompts, slots=1, max_new=6,
                             max_len=48, num_pages=7, prefix_cache=True)
    assert tight == want
    assert et.sched.prefix_hits > 0
    assert et.sched.pool.evictions > 0


# ---------------------------------------------------------- fail fast
def test_prompt_longer_than_max_len_fails_fast(model_params):
    """Satellite: over-long prompts set req.error at submit instead of
    silently clamping and corrupting the cache — on BOTH engines — and
    never reach a dispatch."""
    model, params = model_params
    long_prompt = np.arange(3, 68, dtype=np.int32) % 60 + 3   # 65 > 64-1
    ok_prompt = np.arange(3, 13, dtype=np.int32)
    for make in (lambda: DenseOracle(model, params,
                                    ServingConfig(batch_slots=1,
                                                  max_len=64, eos_id=2)),
                 lambda: make_engine(model, params,
                                     ServingConfig(batch_slots=1,
                                                   max_len=64, eos_id=2,
                                                   page_size=8,
                                                   num_pages=20))):
        eng = make()
        eng.submit(Request(uid=0, prompt=long_prompt, max_new_tokens=4))
        eng.submit(Request(uid=1, prompt=ok_prompt, max_new_tokens=4))
        done = {r.uid: r for r in eng.run()}
        assert len(done) == 2
        assert done[0].error and "max_len" in done[0].error
        assert not done[0].out_tokens
        assert done[1].error is None and len(done[1].out_tokens) == 4


def test_decode_budget_clamped_to_cache_capacity(model_params):
    """Satellite follow-through: a budget that would wrap the cache is
    clamped at admit (identically on both engines) instead of silently
    overwriting the oldest positions."""
    model, params = model_params
    prompt = np.arange(3, 60, dtype=np.int32)                 # 57 tokens
    for toks in (_serve_dense(model, params, [prompt], slots=1,
                              max_new=32),
                 _serve_paged(model, params, [prompt], slots=1,
                              max_new=32, num_pages=20)[0]):
        assert len(toks[0]) <= 64 - len(prompt)


# ------------------------------------------------- multi-query verify
def test_paged_verify_kernel_matches_ref():
    """The (N, g, d) verify read vs the dense multi-query oracle, both
    kernel (interpret) and lax backends."""
    rng = np.random.default_rng(2)
    B, nq, hkv, g, D, P, ps, nmax = 3, 4, 2, 2, 16, 9, 4, 6
    q = jnp.asarray(rng.normal(size=(B, nq, hkv, g, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([0, 7, 19], np.int32))
    want = ref.paged_attention_multi(
        q.reshape(B, nq, hkv * g, D), kp, vp, bt, pos)
    for backend in ("kernel", "lax"):
        got = ops.paged_attention_verify(q, kp, vp, bt, pos,
                                         backend=backend, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got).reshape(B, nq, hkv * g, D), np.asarray(want),
            rtol=2e-5, atol=2e-6, err_msg=backend)


def test_paged_verify_kernel_bf16():
    rng = np.random.default_rng(4)
    B, nq, hkv, g, D, P, ps, nmax = 2, 3, 2, 4, 32, 7, 8, 4
    q = jnp.asarray(rng.normal(size=(B, nq, hkv, g, D))) \
        .astype(jnp.bfloat16)
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D))).astype(jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D))).astype(jnp.bfloat16)
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([5, 26], np.int32))
    want = ref.paged_attention_multi(
        q.astype(jnp.float32).reshape(B, nq, hkv * g, D),
        kp.astype(jnp.float32), vp.astype(jnp.float32), bt, pos)
    got = ops.paged_attention_verify(q, kp, vp, bt, pos, backend="kernel",
                                     interpret=True)
    np.testing.assert_allclose(
        np.asarray(got.astype(jnp.float32).reshape(B, nq, hkv * g, D)),
        np.asarray(want), rtol=3e-2, atol=3e-2)


def test_verify_rows_bitwise_equal_one_token_decode():
    """THE speculative-correctness keystone: verify row i must be
    BITWISE equal to the one-token decode read at position + i (same
    pages, same block tables) — acceptance then trivially reproduces
    one-token streams at any temperature, because the sampler consumes
    identical logits either way."""
    rng = np.random.default_rng(6)
    B, nq, hkv, g, D, P, ps, nmax = 3, 4, 2, 2, 16, 11, 4, 6
    q = jnp.asarray(rng.normal(size=(B, nq, hkv, g, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([2, 9, 17], np.int32))
    ver = np.asarray(ops.paged_attention_verify(q, kp, vp, bt, pos,
                                                backend="lax"))
    for i in range(nq):
        one = np.asarray(ops.paged_attention_decode(
            q[:, i], kp, vp, bt, pos + i, backend="lax"))
        assert (ver[:, i] == one).all(), f"row {i} differs from decode"


# --------------------------------------------------- speculative decode
def test_ngram_draft_most_recent_match():
    from repro.serving.kvpool import NgramDraft
    req = Request(uid=0,
                  prompt=np.asarray([5, 6, 7, 8, 5, 6, 9], np.int32),
                  max_new_tokens=4)
    req.out_tokens = [5, 6]
    # suffix [5, 6] occurs at 0 (-> 7 8) and 4 (-> 9 5 6): the most
    # recent match wins, and the continuation crosses into the output
    out = NgramDraft(max_ngram=3).propose([(0, req, 9, 6)], 3)
    assert out == {0: [9, 5, 6]}
    fresh = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=4)
    assert NgramDraft().propose([(1, fresh, 3, 3)], 3) == {}


def test_speculative_stream_identity_all_sources(model_params):
    """The tentpole acceptance test: speculative decode with EVERY draft
    source is bitwise-identical to one-token decode (and the dense
    engine) on mixed temperatures — acceptance only moves throughput —
    and the verify path compiles exactly ONE decode program."""
    model, params = model_params
    prompts = _prompts(6, seed=13)
    temps = [0.0, 0.9, 0.0, 1.3, 0.6, 0.0]
    want = _serve_dense(model, params, prompts, temps=temps, max_new=10)
    plain, ep = _serve_paged(model, params, prompts, temps=temps,
                             max_new=10)
    assert plain == want
    assert ep.decode_compilations == 1
    for source in ("ngram", "model"):
        got, eng = _serve_paged(model, params, prompts, temps=temps,
                                max_new=10, speculate=3,
                                draft_source=source)
        assert got == want, source
        assert eng.decode_compilations == 1, source
        assert eng.spec_drafted > 0, source
        sp = eng.spec_stats()
        assert 0.0 <= sp["accept_rate"] <= 1.0
        assert sp["effective_tokens_per_step"] >= 1.0, source


def test_speculative_acceptance_extremes(model_params):
    """Acceptance ~1 (greedy self-draft: the drafter IS the target) and
    acceptance ~0 (a garbage drafter: same arch, different init) both
    preserve the streams — acceptance is pure throughput."""
    model, params = model_params
    prompts = _prompts(5, seed=17)
    want = _serve_dense(model, params, prompts, max_new=10)
    hi, eng_hi = _serve_paged(model, params, prompts, max_new=10,
                              speculate=3, draft_source="model")
    assert hi == want
    assert eng_hi.spec_stats()["accept_rate"] > 0.9
    assert eng_hi.spec_stats()["effective_tokens_per_step"] > 1.5
    garbage = model.init(jax.random.PRNGKey(99))
    lo, eng_lo = _serve_paged(model, params, prompts, max_new=10,
                              speculate=3, draft_source="model",
                              draft_model=model, draft_params=garbage)
    assert lo == want
    assert eng_lo.spec_stats()["accept_rate"] < \
        eng_hi.spec_stats()["accept_rate"]


def test_speculative_mixed_adapters_token_identical(model_params,
                                                    tmp_path):
    """Speculation composes with DeltaHub mixed-adapter batching: the
    base-model drafter proposes, each request's merged adapter verifies,
    streams match the dense engine serving the same adapters."""
    from test_serving_delta import _tiny_delta
    from repro.serving import AdapterStore
    model, base = model_params
    d1, _ = _tiny_delta(model, base, 11, tmp_path, "a")
    d2, _ = _tiny_delta(model, base, 22, tmp_path, "b")

    def store():
        s = AdapterStore(base, backend="kernel")
        s.load("a", d1)
        s.load("b", d2)
        return s

    prompts = _prompts(6, seed=5)
    ids = ["a", "b", None, "a", "b", None]
    want = _serve_dense(model, base, prompts, adapters=store(),
                        adapter_ids=ids)
    for source in ("ngram", "model"):
        got, eng = _serve_paged(model, base, prompts, adapters=store(),
                                adapter_ids=ids, speculate=3,
                                draft_source=source)
        assert got == want, source
        assert eng.decode_compilations == 1


def test_speculative_refuses_non_dense_families():
    """MoE routes experts by the dispatch's token count (an N-token
    verify would re-route real tokens vs one-token decode); the zamba
    hybrid's mamba state cannot rewind rejected drafts — both refused
    up front."""
    moe = ModelConfig(family="moe", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97,
                      num_experts=4, num_experts_per_tok=2)
    model = build_model(moe)
    with pytest.raises(ValueError, match="dense-family only"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    ServingConfig(speculate=2))
    zam = ModelConfig(family="hybrid", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=97, shared_attn_period=2)
    model = build_model(zam)
    with pytest.raises(ValueError, match="dense-family only"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    ServingConfig(speculate=2))


# --------------------------------------- scheduler multi-token growth
def test_scheduler_multi_token_growth_accounting():
    """grow() covers [position, position + n) across page boundaries,
    refuses n above the declared per-step maximum, and try_extend()
    never preempts or stalls for optional (draft) tokens."""
    pool = KVPool(num_pages=6, page_size=4)
    sched = PagedScheduler(pool, 2, max_step_tokens=3)
    seq = sched.place(Request(uid=0, prompt=np.arange(3, 7, dtype=np.int32),
                              max_new_tokens=16), 0)
    assert seq is not None and len(seq.pages) == 1
    with pytest.raises(ValueError, match="max_step_tokens"):
        sched.grow(seq, 4, 4)
    ok, preempted = sched.grow(seq, 4, 3)        # covers [4, 7) -> page 2
    assert ok and not preempted and len(seq.pages) == 2
    other = sched.place(Request(uid=1,
                                prompt=np.arange(3, 15, dtype=np.int32),
                                max_new_tokens=4), 1)
    assert other is not None and len(other.pages) == 3   # pool now full
    # best-effort draft growth: no free page -> clamps to the allocated
    # coverage (position 7 is page 1's last slot: exactly 1 token fits)
    assert sched.try_extend(seq, 7, 3) == 1
    assert sched.preemptions == 0 and sched.stalls == 0
    assert len(seq.pages) == 2                   # nothing stolen
    # MANDATORY growth at the same spot preempts by policy instead
    ok, preempted = sched.grow(seq, 7, 2)
    assert ok and preempted == [1]
    assert sched.preemptions == 1

    with pytest.raises(ValueError, match="max_step_tokens"):
        PagedScheduler(pool, 1, max_step_tokens=0)


def test_speculative_growth_storm_deadlock_break(model_params):
    """Regression: N tokens/step growth under the stall policy on a pool
    sized near one sequence must still break the all-stalled deadlock by
    forced preemption (not livelock), and the streams must survive the
    restarts untouched."""
    model, params = model_params
    prompts = _prompts(5, seed=23, lo=10, hi=14)
    want = _serve_dense(model, params, prompts, max_new=12, max_len=32)
    got, eng = _serve_paged(model, params, prompts, max_new=12,
                            max_len=32, page_size=4, num_pages=9,
                            exhaustion="stall", speculate=3,
                            draft_source="ngram")
    assert got == want
    assert eng.sched.stalls > 0
    assert eng.sched.forced_preemptions > 0
    assert eng.decode_compilations == 1
