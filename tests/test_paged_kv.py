"""PagedKV subsystem (DESIGN.md §5): the block-paged pool allocator, the
paged-attention kernel vs its dense reference, and the acceptance proof —
the continuous-batching paged engine is token-identical to the dense-cache
engine on mixed-length (and mixed-adapter, mixed-temperature) request
streams, under monolithic and chunked prefill, through page exhaustion
(preemption / stalling) and prefix-page sharing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import ModelConfig, build_model
from repro.serving.engine import Engine, EngineConfig, Request
from repro.serving.kvpool import (KVPool, PagedEngine, PagedEngineConfig,
                                  TRASH_PAGE)

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)


@pytest.fixture(scope="module")
def model_params():
    model = build_model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(n, seed=3, lo=3, hi=40):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve_dense(model, params, prompts, *, temps=None, max_new=8,
                 slots=3, max_len=64, adapters=None, adapter_ids=None):
    eng = Engine(model, params, EngineConfig(
        batch_slots=slots, max_len=max_len, eos_id=2), adapters=adapters)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           temperature=temps[i] if temps else 0.0,
                           adapter_id=adapter_ids[i] if adapter_ids
                           else None))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.uid: tuple(r.out_tokens) for r in done}


def _serve_paged(model, params, prompts, *, temps=None, max_new=8,
                 slots=3, max_len=64, page_size=8, num_pages=40,
                 adapters=None, adapter_ids=None, **kw):
    eng = PagedEngine(model, params, PagedEngineConfig(
        batch_slots=slots, max_len=max_len, eos_id=2, page_size=page_size,
        num_pages=num_pages, **kw), adapters=adapters)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           temperature=temps[i] if temps else 0.0,
                           adapter_id=adapter_ids[i] if adapter_ids
                           else None))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.uid: tuple(r.out_tokens) for r in done}, eng


# ------------------------------------------------------------ pool unit
def test_pool_alloc_release_refcount():
    pool = KVPool(num_pages=6, page_size=4)
    a = pool.alloc(3)
    assert a is not None and TRASH_PAGE not in a     # page 0 reserved
    assert pool.pages_in_use() == 3
    assert pool.alloc(3) is None                     # only 2 left
    b = pool.alloc(2)
    assert set(a) & set(b) == set()
    pool.retain(a[0])
    pool.release(a[0])
    assert pool.alloc(1) is None                     # still referenced
    pool.release(a[0])
    assert pool.alloc(1) == [a[0]]                   # refcount hit 0
    assert pool.peak_pages_in_use == 5


def test_pool_prefix_cache_refcounts_and_eviction():
    pool = KVPool(num_pages=5, page_size=4)
    pages = pool.alloc(3)
    pool.cache_put("c0", pages[0])                   # cache takes a ref
    pool.cache_put("c1", pages[1])
    for p in pages:
        pool.release(p)                              # request finished
    assert pool.pages_in_use() == 2                  # cached pages pinned
    got = pool.cache_get("c0")
    assert got == pages[0]
    # a full-pool alloc evicts only UNREFERENCED cached pages (c1), then
    # fails rather than stealing c0 (a live request holds it)
    assert pool.alloc(4) is None
    assert pool.evictions == 1
    assert pool.cache_get("c1") is None
    pool.release(got)
    assert pool.alloc(4) is not None                 # c0 evictable now
    assert pool.evictions == 2


def test_pool_rejects_degenerate_sizes():
    with pytest.raises(ValueError):
        KVPool(num_pages=1, page_size=4)
    with pytest.raises(ValueError):
        KVPool(num_pages=4, page_size=0)


# ------------------------------------------------------- kernel parity
def test_paged_attention_kernel_matches_ref():
    rng = np.random.default_rng(0)
    B, hkv, g, D, P, ps, nmax = 3, 2, 2, 16, 9, 4, 6
    q = jnp.asarray(rng.normal(size=(B, hkv, g, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([0, 9, 23], np.int32))
    want = ref.paged_attention(q.reshape(B, hkv * g, D), kp, vp, bt,
                               pos).reshape(B, hkv, g, D)
    for backend in ("kernel", "lax"):
        got = ops.paged_attention_decode(q, kp, vp, bt, pos,
                                         backend=backend, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6,
                                   err_msg=backend)


def test_paged_attention_kernel_bf16():
    rng = np.random.default_rng(1)
    B, hkv, g, D, P, ps, nmax = 2, 2, 4, 32, 7, 8, 4
    q = jnp.asarray(rng.normal(size=(B, hkv, g, D))).astype(jnp.bfloat16)
    kp = jnp.asarray(rng.normal(size=(P, ps, hkv, D))).astype(jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(P, ps, hkv, D))).astype(jnp.bfloat16)
    bt = jnp.asarray(rng.integers(1, P, size=(B, nmax)).astype(np.int32))
    pos = jnp.asarray(np.array([5, 30], np.int32))
    want = ref.paged_attention(
        q.astype(jnp.float32).reshape(B, hkv * g, D),
        kp.astype(jnp.float32), vp.astype(jnp.float32), bt, pos)
    got = ops.paged_attention_decode(q, kp, vp, bt, pos, backend="kernel",
                                     interpret=True)
    np.testing.assert_allclose(
        np.asarray(got.astype(jnp.float32).reshape(B, hkv * g, D)),
        np.asarray(want), rtol=3e-2, atol=3e-2)


# -------------------------------------------------- engine token identity
def test_paged_engine_token_identical_mixed_temperatures(model_params):
    """The acceptance proof at its strongest: mixed prompt lengths AND
    mixed temperatures, monolithic-prefill paged engine vs dense engine.
    Per-request rng + the bitwise-matching monolithic prefill/decode path
    make even sampled streams identical."""
    model, params = model_params
    prompts = _prompts(8)
    temps = [0.0, 0.8, 0.0, 1.2, 0.0, 0.5, 0.0, 0.9]
    want = _serve_dense(model, params, prompts, temps=temps)
    got, eng = _serve_paged(model, params, prompts, temps=temps)
    assert got == want
    st = eng.kv_stats()
    assert st["kv_bytes_ratio"] < 1.0      # bounded by live tokens...
    assert st["within_live_bound"]         # ...not slots x max_len


def test_chunked_prefill_token_identical_and_one_program(model_params):
    """Chunked prefill interleaves with decode and stays token-identical
    to both the dense engine and the monolithic paged engine — through
    ONE compiled prefill program (fixed chunk shape), not one per length
    bucket."""
    model, params = model_params
    prompts = _prompts(8, seed=11, lo=3, hi=60)
    want = _serve_dense(model, params, prompts, max_len=96)
    got, eng = _serve_paged(model, params, prompts, max_len=96,
                            num_pages=60, chunked_prefill=True,
                            prefill_chunk=16)
    assert got == want
    assert eng.prefill_chunks > len(prompts)     # long prompts chunked
    assert eng.prefill_compilations == 1         # one (C, mode) program


@pytest.mark.parametrize("family, kw", [
    ("moe", dict(num_experts=4, num_experts_per_tok=2)),
    ("hybrid", dict(num_heads=4, head_dim=32, shared_attn_period=2,
                    num_layers=4)),
])
def test_paged_engine_families_token_identical(family, kw):
    """MoE pages its KV with exact-length prefill (capacity dispatch is
    pad/chunk-sensitive); the zamba hybrid pages its shared-attention KV
    while the mamba backbone keeps fixed spliced recurrent state."""
    cfg = ModelConfig(family=family, d_model=64, num_kv_heads=2, d_ff=128,
                      vocab_size=97,
                      num_layers=kw.pop("num_layers", 2),
                      num_heads=kw.pop("num_heads", 4),
                      head_dim=kw.pop("head_dim", 16), **kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(5, seed=7)
    want = _serve_dense(model, params, prompts, slots=2, max_new=6)
    got, eng = _serve_paged(model, params, prompts, slots=2, max_new=6,
                            num_pages=30)
    assert got == want
    assert not eng._chunked and not eng.sched.prefix_cache  # gated off


def test_engine_refuses_stateful_and_swa_families():
    rw = ModelConfig(family="rwkv6", num_layers=2, d_model=64, num_heads=2,
                     num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=97)
    model = build_model(rw)
    with pytest.raises(ValueError, match="recurrent"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    PagedEngineConfig())
    swa = CFG.replace(sliding_window=32)
    model = build_model(swa)
    with pytest.raises(ValueError, match="window"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    PagedEngineConfig())
    # hybrid + stall: a stalled slot's mamba state would advance on dummy
    # dispatch inputs — refused up front (preempt restarts cleanly)
    zam = ModelConfig(family="hybrid", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=97, shared_attn_period=2)
    model = build_model(zam)
    with pytest.raises(ValueError, match="stall"):
        PagedEngine(model, model.init(jax.random.PRNGKey(0)),
                    PagedEngineConfig(exhaustion="stall"))


def test_mixed_adapter_stream_token_identical(model_params, tmp_path):
    """Mixed-adapter continuous batching through the pool: every request
    matches the dense engine serving the same adapters."""
    from test_serving_delta import _tiny_delta
    from repro.serving.engine import AdapterStore
    model, base = model_params
    d1, _ = _tiny_delta(model, base, 11, tmp_path, "a")
    d2, _ = _tiny_delta(model, base, 22, tmp_path, "b")

    def store():
        s = AdapterStore(base, backend="kernel")
        s.load("a", d1)
        s.load("b", d2)
        return s

    prompts = _prompts(6, seed=5)
    ids = ["a", "b", None, "a", "b", None]
    want = _serve_dense(model, base, prompts, adapters=store(),
                        adapter_ids=ids)
    got, _ = _serve_paged(model, base, prompts, adapters=store(),
                          adapter_ids=ids)
    assert got == want


# ------------------------------------------------- exhaustion / eviction
def test_page_exhaustion_preempt_and_stall(model_params):
    """A pool far smaller than slots x max_len still completes every
    request with identical tokens: 'preempt' restarts the youngest
    sequence (per-request rng regenerates the same stream), 'stall'
    parks the growing sequence until pages free up."""
    model, params = model_params
    prompts = _prompts(6, seed=5, lo=20, hi=48)
    want = _serve_dense(model, params, prompts, max_new=10)
    roomy, _ = _serve_paged(model, params, prompts, max_new=10,
                            num_pages=60)
    assert roomy == want
    tight_p, ep = _serve_paged(model, params, prompts, max_new=10,
                               num_pages=10, exhaustion="preempt")
    assert tight_p == want
    assert ep.sched.preemptions > 0
    tight_s, es = _serve_paged(model, params, prompts, max_new=10,
                               num_pages=10, exhaustion="stall")
    assert tight_s == want
    assert es.sched.stalls > 0


def test_prefix_cache_reuse_and_eviction(model_params):
    """Shared-prefix requests reuse reference-counted prefix pages
    (token-identical, fewer prefill tokens computed); pool pressure
    evicts only unreferenced cached pages."""
    model, params = model_params
    rng = np.random.default_rng(9)
    pre_a = rng.integers(3, 90, size=24).astype(np.int32)
    pre_b = rng.integers(3, 90, size=24).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(3, 90,
                                                 size=6).astype(np.int32)])
               for pre in (pre_a, pre_a, pre_a, pre_b, pre_b)]
    want = _serve_dense(model, params, prompts, slots=2, max_new=6,
                        max_len=48)
    got, eng = _serve_paged(model, params, prompts, slots=2, max_new=6,
                            max_len=48, prefix_cache=True)
    assert got == want
    assert eng.sched.prefix_hits > 0
    # under pressure the cache gives unreferenced pages back (prefix B
    # evicts prefix A's cached pages) instead of starving admissions
    tight, et = _serve_paged(model, params, prompts, slots=1, max_new=6,
                             max_len=48, num_pages=7, prefix_cache=True)
    assert tight == want
    assert et.sched.prefix_hits > 0
    assert et.sched.pool.evictions > 0


# ---------------------------------------------------------- fail fast
def test_prompt_longer_than_max_len_fails_fast(model_params):
    """Satellite: over-long prompts set req.error at submit instead of
    silently clamping and corrupting the cache — on BOTH engines — and
    never reach a dispatch."""
    model, params = model_params
    long_prompt = np.arange(3, 68, dtype=np.int32) % 60 + 3   # 65 > 64-1
    ok_prompt = np.arange(3, 13, dtype=np.int32)
    for make in (lambda: Engine(model, params,
                                EngineConfig(batch_slots=1, max_len=64,
                                             eos_id=2)),
                 lambda: PagedEngine(model, params,
                                     PagedEngineConfig(batch_slots=1,
                                                       max_len=64,
                                                       eos_id=2,
                                                       page_size=8,
                                                       num_pages=20))):
        eng = make()
        eng.submit(Request(uid=0, prompt=long_prompt, max_new_tokens=4))
        eng.submit(Request(uid=1, prompt=ok_prompt, max_new_tokens=4))
        done = {r.uid: r for r in eng.run()}
        assert len(done) == 2
        assert done[0].error and "max_len" in done[0].error
        assert not done[0].out_tokens
        assert done[1].error is None and len(done[1].out_tokens) == 4


def test_decode_budget_clamped_to_cache_capacity(model_params):
    """Satellite follow-through: a budget that would wrap the cache is
    clamped at admit (identically on both engines) instead of silently
    overwriting the oldest positions."""
    model, params = model_params
    prompt = np.arange(3, 60, dtype=np.int32)                 # 57 tokens
    for toks in (_serve_dense(model, params, [prompt], slots=1,
                              max_new=32),
                 _serve_paged(model, params, [prompt], slots=1,
                              max_new=32, num_pages=20)[0]):
        assert len(toks[0]) <= 64 - len(prompt)
