"""Lowering machinery on the local 1-device mesh: every builder must
lower+compile for a smoke config (the 512-device production sweep runs via
launch/dryrun.py; this guards the plumbing in-process)."""
import jax
import pytest

from repro.configs import LM_SHAPES, get_arch
from repro.configs.base import ShapeSpec
from repro.launch.lowering import (build_cell, build_refresh, DEFAULT_LIFT,
                                   cost_analysis_dict)
from repro.launch.mesh import make_host_mesh

TINY_TRAIN = ShapeSpec("train_tiny", 32, 4, "train")
TINY_PREFILL = ShapeSpec("prefill_tiny", 32, 2, "prefill")
TINY_DECODE = ShapeSpec("decode_tiny", 32, 2, "decode")

ARCH_SAMPLE = ["qwen3-1.7b", "moonshot-16b-a3b", "rwkv6-1.6b",
               "zamba2-1.2b", "hubert-xlarge"]


def _lower(low):
    jfn = jax.jit(low.fn, in_shardings=low.in_shardings,
                  out_shardings=low.out_shardings,
                  donate_argnums=low.donate)
    return jfn.lower(*low.args).compile()


@pytest.mark.parametrize("arch", ARCH_SAMPLE)
def test_train_lowering_smoke_config(arch):
    bundle = get_arch(arch)
    cfg = bundle.smoke
    mesh = make_host_mesh(1, 1)
    lcfg = DEFAULT_LIFT.replace(rank=4, density=0.05, min_dim=8,
                                k_multiple=8)
    compiled = _lower(build_cell(bundle, cfg, mesh, TINY_TRAIN,
                                 method="lift", lcfg=lcfg))
    ca = cost_analysis_dict(compiled)
    assert ca.get("flops", 0) > 0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-1.6b",
                                  "zamba2-1.2b"])
def test_serve_lowerings_smoke_config(arch):
    bundle = get_arch(arch)
    cfg = bundle.smoke
    mesh = make_host_mesh(1, 1)
    _lower(build_cell(bundle, cfg, mesh, TINY_PREFILL))
    _lower(build_cell(bundle, cfg, mesh, TINY_DECODE))


def test_refresh_lowering_smoke():
    bundle = get_arch("qwen3-1.7b")
    mesh = make_host_mesh(1, 1)
    lcfg = DEFAULT_LIFT.replace(rank=4, min_dim=8, k_multiple=8,
                                method="randomized")
    _lower(build_refresh(bundle, bundle.smoke, mesh, lcfg=lcfg))


def test_encoder_prefill_is_logits():
    bundle = get_arch("hubert-xlarge")
    mesh = make_host_mesh(1, 1)
    low = build_cell(bundle, bundle.smoke, mesh, TINY_PREFILL)
    assert low.meta.get("encoder")
    _lower(low)


def test_shape_table_covers_assignment():
    assert set(LM_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                              "long_500k"}
    s = LM_SHAPES["train_4k"]
    assert (s.seq_len, s.global_batch) == (4096, 256)
    s = LM_SHAPES["prefill_32k"]
    assert (s.seq_len, s.global_batch) == (32768, 32)
    s = LM_SHAPES["decode_32k"]
    assert (s.seq_len, s.global_batch) == (32768, 128)
    s = LM_SHAPES["long_500k"]
    assert (s.seq_len, s.global_batch) == (524288, 1)
