"""Analysis toolkit: alignment score, update rank, perturbation locality."""
import jax
import numpy as np

from repro.core.analysis import (alignment_score, perturb_at_indices,
                                 tree_update_stats, update_rank)
from repro.core.lift import LiftConfig, compute_indices, get_by_path, make_plan
from repro.models import ModelConfig, build_model

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)


def test_alignment_score_identity_is_one():
    w = jax.random.normal(jax.random.PRNGKey(0), (48, 64))
    s = float(alignment_score(w, w, top_n=16))
    assert abs(s - 1.0) < 1e-4


def test_alignment_score_random_rotation_lower():
    w = jax.random.normal(jax.random.PRNGKey(0), (48, 64))
    w2 = jax.random.normal(jax.random.PRNGKey(1), (48, 64))
    s = float(alignment_score(w, w2, top_n=16))
    assert 0.0 <= s < 0.9


def test_update_rank_detects_lowrank_delta():
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 4))
    b = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
    delta = a @ b
    r = int(update_rank(delta))
    assert r == 4, r
    full = jax.random.normal(jax.random.PRNGKey(2), (64, 96))
    assert int(update_rank(full)) > 50


def test_perturbation_only_touches_selected():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    lcfg = LiftConfig(rank=4, match_rank=1, method="exact", min_dim=16)
    plan = make_plan(m.spec(), lcfg)
    idx = compute_indices(params, plan, lcfg, jax.random.PRNGKey(1))
    pert = perturb_at_indices(params, idx, plan, 0.05, jax.random.PRNGKey(2))
    stats = tree_update_stats(params, pert)
    budget = sum(p.k * max(1, int(np.prod(p.stack))) for p in plan.values())
    assert stats["changed"] <= budget
    assert stats["changed"] >= 0.9 * budget  # noise ~never exactly zero
    # unplanned leaves untouched
    assert np.array_equal(np.asarray(get_by_path(params, "embed/table")),
                          np.asarray(get_by_path(pert, "embed/table")))
