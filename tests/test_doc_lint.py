"""Doc lint (tools/doc_lint.py, docs/CI.md): the repo's markdown carries
no dead intra-repo paths, no citations of DESIGN.md sections that don't
exist, and no broken relative links/anchors — and the checker itself
still detects each failure class (so a lint regression can't silently
pass by detecting nothing)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "tools"))

import doc_lint  # noqa: E402


def test_repo_markdown_is_clean():
    errs = doc_lint.lint_repo(ROOT)
    assert errs == [], "\n".join(errs)


def test_cli_exit_status():
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tools", "doc_lint.py")],
                       cwd=ROOT, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr


@pytest.fixture
def toy_repo(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "src" / "repro" / "kernels").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "kernels" / "ops.py").write_text("")
    (tmp_path / "docs" / "OK.md").write_text("# ok\n\n## Real heading\n")
    (tmp_path / "DESIGN.md").write_text(
        "# D\n\n## §1 Overview\n\n## §2 More\n\nbody\n")
    return tmp_path


def _lint(root, name, text):
    (root / name).write_text(text)
    return doc_lint.lint_repo(str(root))


def test_clean_toy_repo(toy_repo):
    errs = _lint(toy_repo, "GOOD.md",
                 "see `kernels/ops.py` / `src/repro/kernels/ops.py` "
                 "(DESIGN.md §2), [link](docs/OK.md#real-heading), "
                 "`kernels/ops.py:helper`, external `foo/bar.py`, "
                 "glob `kernels/*.py`, `--flag`, `/abs/path.py`\n")
    assert errs == []


def test_detects_dead_path(toy_repo):
    errs = _lint(toy_repo, "BAD.md", "see `kernels/nope.py`\n")
    assert len(errs) == 1 and "kernels/nope.py" in errs[0]


def test_detects_bad_section_cite(toy_repo):
    errs = _lint(toy_repo, "BAD.md", "per DESIGN.md §9 the pool...\n")
    assert len(errs) == 1 and "§9" in errs[0]
    # bare §N citations are checked inside DESIGN.md itself
    (toy_repo / "BAD.md").write_text("fixed\n")
    errs = _lint(toy_repo, "DESIGN.md",
                 "# D\n\n## §1 Overview\n\nsee §3\n")
    assert len(errs) == 1 and "§3" in errs[0]


def test_detects_broken_link_and_anchor(toy_repo):
    errs = _lint(toy_repo, "BAD.md",
                 "[a](docs/MISSING.md) [b](docs/OK.md#not-a-heading)\n")
    assert len(errs) == 2
    assert any("MISSING.md" in e for e in errs)
    assert any("#not-a-heading" in e for e in errs)


def test_member_and_dir_references_resolve(toy_repo):
    errs = _lint(toy_repo, "GOOD.md",
                 "`kernels/ops.helper` and `kernels/` and "
                 "`src/repro/kernels/`\n")
    assert errs == []
