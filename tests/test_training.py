"""Trainer integration: every tuning method learns, microbatch accumulation
is exact, LIFT refresh works inside the loop, PEFT merge round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig, get_by_path, make_plan
from repro.core.peft import PeftConfig
from repro.models import ModelConfig, build_model
from repro.training import trainer as T

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)
ADAM = sa.AdamConfig(lr=1e-3)


def _setup(kind, selection="lift"):
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    mcfg = T.MethodConfig(
        kind=kind,
        lift=LiftConfig(rank=8, match_rank=2, method="exact",
                        selection=selection, min_dim=16),
        peft=PeftConfig(rank=4))
    params, state = T.init_train_state(m, params, mcfg,
                                       jax.random.PRNGKey(1))
    step = jax.jit(T.make_train_step(m, mcfg, ADAM, T.constant_lr(1e-3)))
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, 97),
             "labels": jax.random.randint(key, (4, 16), 0, 97),
             "loss_mask": jnp.ones((4, 16))}
    return m, mcfg, params, state, step, batch


@pytest.mark.parametrize("kind", ["full", "lift", "sparse", "lora",
                                  "pissa", "dora"])
def test_method_reduces_loss(kind):
    m, mcfg, params, state, step, batch = _setup(kind)
    losses = []
    for _ in range(8):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (kind, losses)
    assert np.isfinite(losses).all()


def test_lift_freezes_everything_else():
    m, mcfg, params0, state, step, batch = _setup("lift")
    plan = make_plan(m.spec(), mcfg.lift)
    params, state, _ = step(params0, state, batch)
    # embeddings and norms untouched
    for path in ["embed/table", "final_norm/scale", "blocks/ln1/scale"]:
        a = np.asarray(get_by_path(params0, path))
        b = np.asarray(get_by_path(params, path))
        assert np.array_equal(a, b), path
    # planned tensors changed
    assert not np.array_equal(
        np.asarray(get_by_path(params0, "blocks/mlp/up")),
        np.asarray(get_by_path(params, "blocks/mlp/up")))


def test_refresh_mid_training():
    m, mcfg, params, state, step, batch = _setup("lift")
    refresh = jax.jit(T.make_refresh_step(m, mcfg))
    for i in range(4):
        params, state, metrics = step(params, state, batch)
    old_idx = {p: np.asarray(state["opt"]["tensors"][p]["idx"])
               for p in state["opt"]["tensors"]}
    state = refresh(params, state, jax.random.PRNGKey(7))
    # training continues fine after migration
    for i in range(4):
        params, state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # indices refreshed (weights changed -> some movement expected)
    moved = any(not np.array_equal(old_idx[p],
                                   np.asarray(state["opt"]["tensors"][p]["idx"]))
                for p in old_idx)
    assert moved


def test_microbatch_accumulation_exact():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    mcfg = T.MethodConfig(kind="full")
    params0, state0 = T.init_train_state(m, params, mcfg,
                                         jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, 97),
             "labels": jax.random.randint(key, (4, 16), 0, 97),
             "loss_mask": jnp.ones((4, 16))}
    s1 = jax.jit(T.make_train_step(m, mcfg, ADAM, T.constant_lr(1e-3)))
    s2 = jax.jit(T.make_train_step(m, mcfg, ADAM, T.constant_lr(1e-3),
                                   microbatch=2))
    pa, _, _ = s1(params0, state0, batch)
    pb, _, _ = s2(params0, state0, batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    assert err < 2e-5, err


def test_peft_effective_params_differ_from_base():
    m, mcfg, params, state, step, batch = _setup("lora")
    params, state, _ = step(params, state, batch)
    eff = T.effective_params(m, params, state, mcfg)
    # base params frozen, effective differ through adapters
    assert not np.array_equal(
        np.asarray(get_by_path(eff, "blocks/mlp/up")),
        np.asarray(get_by_path(params, "blocks/mlp/up")))


def test_pissa_base_plus_adapter_preserves_function():
    """PiSSA init: W_res + A0 B0 == W, so the initial model is unchanged."""
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None] % 97,
             "labels": jnp.zeros((1, 16), jnp.int32),
             "loss_mask": jnp.ones((1, 16))}
    l0 = float(m.loss(params, batch)[0])
    mcfg = T.MethodConfig(kind="pissa", peft=PeftConfig(rank=16),
                          lift=LiftConfig(min_dim=16))
    base, state = T.init_train_state(m, params, mcfg, jax.random.PRNGKey(1))
    eff = T.effective_params(m, base, state, mcfg)
    l1 = float(m.loss(eff, batch)[0])
    assert abs(l0 - l1) < 5e-3, (l0, l1)


def test_lift_train_other_updates_norms():
    m = build_model(CFG)
    params0 = m.init(jax.random.PRNGKey(0))
    mcfg = T.MethodConfig(kind="lift", lift=LiftConfig(
        rank=8, match_rank=2, method="exact", min_dim=16, train_other=True))
    params, state = T.init_train_state(m, params0, mcfg,
                                       jax.random.PRNGKey(1))
    step = jax.jit(T.make_train_step(m, mcfg, ADAM, T.constant_lr(1e-3)))
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, 97),
             "labels": jax.random.randint(key, (4, 16), 0, 97),
             "loss_mask": jnp.ones((4, 16))}
    params, state, metrics = step(params, state, batch)
    # norms now train (dense), embeddings still frozen
    assert not np.array_equal(
        np.asarray(get_by_path(params0, "final_norm/scale")),
        np.asarray(get_by_path(params, "final_norm/scale")))
    assert np.array_equal(np.asarray(get_by_path(params0, "embed/table")),
                          np.asarray(get_by_path(params, "embed/table")))


def test_moe_grouped_dispatch_matches_ungrouped():
    """G>1 grouped dispatch == G=1 when capacity is non-binding."""
    base = dict(num_layers=1, d_model=32, num_heads=4, num_kv_heads=2,
                head_dim=8, d_ff=48, vocab_size=97, num_experts=4,
                num_experts_per_tok=2, capacity_factor=8.0)
    m1 = build_model(ModelConfig(family="moe", moe_groups=1, **base))
    m4 = build_model(ModelConfig(family="moe", moe_groups=4, **base))
    params = m1.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, 97),
             "labels": jax.random.randint(key, (4, 16), 0, 97),
             "loss_mask": jnp.ones((4, 16))}
    l1 = float(m1.loss(params, batch)[0])
    l4 = float(m4.loss(params, batch)[0])
    assert abs(l1 - l4) < 1e-5, (l1, l4)


def test_schedules():
    sched = T.warmup_linear(100, warmup_ratio=0.1, peak=1e-3)
    assert float(sched(jnp.asarray(0))) < 2e-4
    assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(sched(jnp.asarray(99))) < 2e-4
