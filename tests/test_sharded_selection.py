"""Sharded streaming selection parity (DESIGN.md §3).

The shard_map'd SelectionEngine path (per-shard histograms psum'd into
the threshold search, shard-local compaction, O(k) all-gather merge) must
return IDENTICAL index sets to the single-device engine:

  * quota="global": bitwise-identical — the psum'd integer histograms
    drive the same binary search to the same tau, so the candidate set
    (and its sorted k-prefix) cannot differ;
  * quota="local": bitwise-identical per construction — each shard runs
    the exact single-device per-slab pipeline (`_lift_indices_body`);
  * dense reference: the streaming paths agree with |A Bᵀ| -> lax.top_k
    up to final-histogram-bin ties (bounded at 1e-3 of k).

Runs in a subprocess (like test_distributed) so the 8 placeholder host
devices never leak into other tests; the multi-device parity matrix
(2, 4, 8 shards) lives in ONE subprocess to amortize jax startup.
In-process tests cover the single-device pieces: ragged-quota
validation, the per-slab streaming-local kernel, and the engine's
quota="local" unification with core/local_quota.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lift import LiftConfig, TensorPlan
from repro.core.local_quota import compute_indices_local, local_topk_indices
from repro.core.selection import SelectionEngine
from repro.kernels import ops as kops

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.lift import LiftConfig, TensorPlan
from repro.core.selection import SelectionEngine
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import sharding_ctx

GEOMS = [((2,), 128, 192, 0.05),   # stacked batch, rectangular
         ((), 96, 128, 0.02)]      # single matrix, second kernel geometry


def make_case(stack, rows, cols, density, seed):
    k = max(8, int(density * rows * cols) // 8 * 8)
    shape = tuple(stack) + (rows, cols)
    plan = {"t": TensorPlan("t", shape, tuple(stack), rows, cols, k)}
    w = jax.random.normal(jax.random.PRNGKey(seed), shape)
    return plan, {"t": w}, k


CFG = LiftConfig(rank=8, method="exact", min_dim=16, use_kernel=True)

# ---- global quota: sharded == single-device, bitwise, per geometry group
for gi, (stack, rows, cols, density) in enumerate(GEOMS):
    plan, params, k = make_case(stack, rows, cols, density, seed=10 + gi)
    ref_eng = SelectionEngine(plan, CFG)
    assert ref_eng.group_exec == {(rows, cols, k): "streaming"}
    ref_idx, ref_stats = ref_eng.select_with_stats(params,
                                                   jax.random.PRNGKey(3))
    assert int(ref_stats["overflow"]) == 0
    # dense reference (|A B^T| -> lax.top_k) for the same group
    dense_idx = SelectionEngine(plan, CFG.replace(use_kernel=False)).select(
        params, jax.random.PRNGKey(3))
    ns = max(1, int(np.prod(stack)))
    agree = min(
        len(np.intersect1d(np.asarray(dense_idx["t"]).reshape(ns, k)[i],
                           np.asarray(ref_idx["t"]).reshape(ns, k)[i])) / k
        for i in range(ns))
    assert agree >= 1 - 1e-3, agree
    for n_model in (2, 4, 8):
        mesh = make_host_mesh(8 // n_model, n_model)
        with sharding_ctx(mesh):
            eng = SelectionEngine(plan, CFG)
        assert eng.group_exec == {(rows, cols, k): "sharded"}, eng.group_exec
        idx, stats = eng.select_with_stats(params, jax.random.PRNGKey(3))
        assert np.array_equal(np.asarray(idx["t"]), np.asarray(ref_idx["t"])), \
            (stack, rows, cols, n_model)
        assert int(stats["overflow"]) == 0
print("PARITY-GLOBAL-OK")

# ---- local quota: sharded-local == streaming-local, bitwise
for n_model in (2, 4, 8):
    plan, params, k = make_case((2,), 128, 192, 0.05, seed=21)
    cfgl = CFG.replace(quota="local", quota_shards=n_model)
    ref_eng = SelectionEngine(plan, cfgl)
    assert ref_eng.group_exec == {(128, 192, k): "streaming-local"}
    ref_idx = ref_eng.select(params, jax.random.PRNGKey(5))
    mesh = make_host_mesh(8 // n_model, n_model)
    with sharding_ctx(mesh):
        eng = SelectionEngine(plan, cfgl)
    assert eng.group_exec == {(128, 192, k): "sharded-local"}
    idx = eng.select(params, jax.random.PRNGKey(5))
    assert np.array_equal(np.asarray(idx["t"]), np.asarray(ref_idx["t"])), \
        n_model
    # dense local-quota reference agrees up to final-bin ties
    dl = SelectionEngine(plan, cfgl.replace(use_kernel=False)).select(
        params, jax.random.PRNGKey(5))
    agree = min(
        len(np.intersect1d(np.asarray(dl["t"])[i],
                           np.asarray(idx["t"])[i])) / k for i in range(2))
    assert agree >= 1 - 1e-3, agree
print("PARITY-LOCAL-OK")

# ---- geometry that does not divide over the mesh falls back, same result
plan, params, k = make_case((), 96, 100, 0.05, seed=31)   # 100 % 8 != 0
ref_idx = SelectionEngine(plan, CFG).select(params, jax.random.PRNGKey(7))
mesh = make_host_mesh(1, 8)
with sharding_ctx(mesh):
    eng = SelectionEngine(plan, CFG)
assert eng.group_exec == {(96, 100, k): "streaming"}, eng.group_exec
idx = eng.select(params, jax.random.PRNGKey(7))
assert np.array_equal(np.asarray(idx["t"]), np.asarray(ref_idx["t"]))
print("FALLBACK-OK")

# ---- overflow path: adversarial mass in one tile, tiny capacity — both
# paths must report the overflow and still return only in-range indices
m = n = 256
a = jnp.ones((m, 1)).at[128:].set(1e-3)
b = jnp.ones((n, 1)).at[128:].set(1e-3)
k = 512
s_idx, _tau, s_ovf = kops.lift_indices(a, b, k, capacity=128, bm=128, bn=128)
assert int(s_ovf) > 0
mesh = make_host_mesh(1, 8)
f = jax.jit(shard_map(
    partial(kops.lift_indices_sharded, k=k, axis_name="model", n_shards=8,
            cols_global=n, capacity=128, bm=128, bn=128),
    mesh=mesh, in_specs=(P(), P("model", None)),
    out_specs=(P(), P(), P()), check_rep=False))
d_idx, _tau, d_ovf = f(a, b)
assert int(d_ovf) > 0, int(d_ovf)
d_idx = np.asarray(d_idx)
assert d_idx.shape == (k,)
assert d_idx.min() >= 0 and d_idx.max() < m * n   # sentinels never leak
print("OVERFLOW-OK")

# ---- STRUCTURED (block_size > 1): sharded == single-device, bitwise,
# for both quotas — the block-summing collective path (per-shard block
# histograms psum'd into the threshold search, block-aligned shard-local
# compaction, O(k/bs^2) block all-gather + replicated expansion)
BS = 4
rows_s, cols_s, k_s = 128, 192, 1216
plan, params, _ = make_case((2,), rows_s, cols_s, 0.05, seed=51)
plan = {"t": TensorPlan("t", (2, rows_s, cols_s), (2,), rows_s, cols_s, k_s)}
cfgs = CFG.replace(block_size=BS)
ref_eng = SelectionEngine(plan, cfgs)
assert ref_eng.backend == "streaming"
assert ref_eng.group_exec == {(rows_s, cols_s, k_s): "streaming"}
ref_idx, ref_stats = ref_eng.select_with_stats(params, jax.random.PRNGKey(3))
assert int(ref_stats["overflow"]) == 0
# dense structured reference: bitwise on this case (block sums don't tie)
dense_idx = SelectionEngine(plan, cfgs.replace(use_kernel=False)).select(
    params, jax.random.PRNGKey(3))
assert np.array_equal(np.asarray(dense_idx["t"]), np.asarray(ref_idx["t"]))
for n_model in (2, 4, 8):
    mesh = make_host_mesh(8 // n_model, n_model)
    with sharding_ctx(mesh):
        eng = SelectionEngine(plan, cfgs)
    assert eng.group_exec == {(rows_s, cols_s, k_s): "sharded"}, \
        eng.group_exec
    idx, stats = eng.select_with_stats(params, jax.random.PRNGKey(3))
    assert np.array_equal(np.asarray(idx["t"]), np.asarray(ref_idx["t"])), \
        n_model
    assert int(stats["overflow"]) == 0
cfgl = cfgs.replace(quota="local", quota_shards=4)
ref_local = SelectionEngine(plan, cfgl).select(params, jax.random.PRNGKey(5))
mesh = make_host_mesh(2, 4)
with sharding_ctx(mesh):
    eng = SelectionEngine(plan, cfgl)
assert eng.group_exec == {(rows_s, cols_s, k_s): "sharded-local"}
idx = eng.select(params, jax.random.PRNGKey(5))
assert np.array_equal(np.asarray(idx["t"]), np.asarray(ref_local["t"]))
# a slab that does not tile into blocks falls back (192/8=24 ok, use 8
# shards with bs=16: 192/8=24 % 16 != 0)
with sharding_ctx(make_host_mesh(1, 8)):
    eng16 = SelectionEngine(plan16 := {"t": TensorPlan(
        "t", (rows_s, cols_s), (), rows_s, cols_s, 768)},
        CFG.replace(block_size=16))
assert eng16.group_exec == {(rows_s, cols_s, 768): "streaming"}, \
    eng16.group_exec
print("PARITY-STRUCTURED-OK")

# ---- dense fallback backends under the mesh: per-shard top_k + O(k)
# merge, bitwise vs single device (no full-tensor gather, ROADMAP PR 2
# follow-up)
plan, params, k = make_case((2,), 128, 192, 0.05, seed=61)
grads = {"t": jax.random.normal(jax.random.PRNGKey(62), params["t"].shape)}
for sel in ("magnitude", "random", "gradient", "movement"):
    need_g = sel in ("gradient", "movement")
    cfgd = LiftConfig(selection=sel, min_dim=16)
    ref_idx = SelectionEngine(plan, cfgd).select(
        params, jax.random.PRNGKey(7), grads if need_g else None)
    for n_model in (2, 4, 8):
        mesh = make_host_mesh(8 // n_model, n_model)
        with sharding_ctx(mesh):
            eng = SelectionEngine(plan, cfgd)
        assert eng.group_exec == {(128, 192, k): "dense-sharded"}, \
            (sel, eng.group_exec)
        idx = eng.select(params, jax.random.PRNGKey(7),
                         grads if need_g else None)
        assert np.array_equal(np.asarray(idx["t"]),
                              np.asarray(ref_idx["t"])), (sel, n_model)
# structured magnitude: block-summed local scores, still bitwise
cfgm = LiftConfig(selection="magnitude", min_dim=16, block_size=4)
plan_b = {"t": TensorPlan("t", (2, 128, 192), (2,), 128, 192, 1216)}
ref_idx = SelectionEngine(plan_b, cfgm).select(params, jax.random.PRNGKey(8))
with sharding_ctx(make_host_mesh(1, 8)):
    eng = SelectionEngine(plan_b, cfgm)
assert eng.group_exec == {(128, 192, 1216): "dense-sharded"}
idx = eng.select(params, jax.random.PRNGKey(8))
assert np.array_equal(np.asarray(idx["t"]), np.asarray(ref_idx["t"]))
# dense "lift" (needs the full W for factorization) stays unsharded
with sharding_ctx(make_host_mesh(1, 8)):
    engl = SelectionEngine(plan, CFG.replace(use_kernel=False))
assert engl.group_exec == {(128, 192, k): "dense"}, engl.group_exec
print("DENSE-SHARDED-OK")

# ---- fused refresh (select + migrate) under the mesh matches unsharded
from repro.core import sparse_adam as sa
plan, params, k = make_case((2,), 128, 192, 0.05, seed=41)
ref_eng = SelectionEngine(plan, CFG)
idx0 = ref_eng.select(params, jax.random.PRNGKey(0))
state = sa.init_state(params, idx0, plan)
params2 = {"t": params["t"] + 0.3 * jax.random.normal(
    jax.random.PRNGKey(9), params["t"].shape)}
ref_opt, _ = ref_eng.refresh_opt(params2, state, jax.random.PRNGKey(2))
mesh = make_host_mesh(2, 4)
with sharding_ctx(mesh):
    eng = SelectionEngine(plan, CFG)
opt, _ = eng.refresh_opt(params2, state, jax.random.PRNGKey(2))
for leaf in ("idx", "m", "v"):
    assert np.array_equal(np.asarray(opt["tensors"]["t"][leaf]),
                          np.asarray(ref_opt["tensors"]["t"][leaf])), leaf
print("REFRESH-OK")
"""


def test_sharded_selection_parity_matrix():
    """2/4/8-shard engine parity vs single device: global quota bitwise,
    local quota bitwise, dense-ref agreement, fallback, overflow, fused
    refresh — one subprocess so the 8 host devices stay contained."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    for marker in ("PARITY-GLOBAL-OK", "PARITY-LOCAL-OK",
                   "PARITY-STRUCTURED-OK", "DENSE-SHARDED-OK",
                   "FALLBACK-OK", "OVERFLOW-OK", "REFRESH-OK"):
        assert marker in r.stdout, (marker, r.stdout)


# --------------------------------------------- single-device local pieces
def _plan(stack, rows, cols, k):
    shape = tuple(stack) + (rows, cols)
    return {"t": TensorPlan("t", shape, tuple(stack), rows, cols, k)}


@pytest.mark.parametrize("block_size", [1, 4])
def test_lift_indices_local_matches_per_slab_reference(block_size):
    """The fused local-quota kernel path == running `lift_indices` slab by
    slab with offset columns (the definition of a per-shard quota) — at
    both structure granularities."""
    rows, cols, k, n_shards = 96, 128, 256, 4
    a = jax.random.normal(jax.random.PRNGKey(0), (rows, 8))
    b = jax.random.normal(jax.random.PRNGKey(1), (cols, 8))
    idx, taus, ovf = kops.lift_indices_local(a, b, k, n_shards,
                                             block_size=block_size)
    assert int(ovf) == 0
    w = cols // n_shards
    parts = []
    for j in range(n_shards):
        ij, _t, _o = kops.lift_indices(a, b[j * w:(j + 1) * w],
                                       k // n_shards,
                                       block_size=block_size)
        parts.append(np.asarray(ij) // w * cols + j * w + np.asarray(ij) % w)
    ref = np.sort(np.concatenate(parts))
    assert np.array_equal(np.asarray(idx), ref)
    assert taus.shape == (n_shards,)


def test_engine_local_quota_unifies_compute_indices_local():
    """`compute_indices_local` (the historical side path) now routes
    through SelectionEngine(quota='local') — both entry points must give
    the same indices, and the dense engine must satisfy the per-slab
    budget exactly."""
    rows, cols, k, n = 64, 96, 192, 4
    plan = _plan((1,), rows, cols, k)
    params = {"t": jax.random.normal(jax.random.PRNGKey(2), (1, rows, cols))}
    cfg = LiftConfig(rank=8, method="exact", min_dim=16)
    via_wrapper = compute_indices_local(params, plan, cfg,
                                        jax.random.PRNGKey(3), n_shards=n)
    eng = SelectionEngine(plan, cfg.replace(quota="local", quota_shards=n))
    assert eng.group_exec == {(rows, cols, k): "dense"}
    via_engine = eng.select(params, jax.random.PRNGKey(3))
    assert np.array_equal(np.asarray(via_wrapper["t"]),
                          np.asarray(via_engine["t"]))
    sel = np.asarray(via_engine["t"])[0]
    shard = (sel % cols) // (cols // n)
    assert (np.bincount(shard, minlength=n) == k // n).all()


def test_engine_rejects_ragged_local_quota_with_tensor_path():
    """cols or k not divisible by the quota shards must fail LOUDLY at
    engine construction, naming the offending tensor."""
    plan = _plan((), 64, 100, 200)        # cols 100 % 8 != 0
    with pytest.raises(ValueError, match=r"'t'"):
        SelectionEngine(plan, LiftConfig(quota="local", quota_shards=8))
    plan2 = _plan((), 64, 96, 200)        # k 200 % 16 != 0
    with pytest.raises(ValueError, match="divisible"):
        SelectionEngine(plan2, LiftConfig(quota="local", quota_shards=16))
    with pytest.raises(ValueError, match="quota mode"):
        SelectionEngine(plan2, LiftConfig(quota="nope"))


def test_local_topk_indices_rejects_ragged():
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (32, 60)))
    with pytest.raises(ValueError, match="divisible"):
        local_topk_indices(s, 64, 8)      # 60 % 8 != 0
    with pytest.raises(ValueError, match="divisible"):
        local_topk_indices(s, 30, 4)      # k 30 % 4 != 0
    with pytest.raises(ValueError, match="divisible"):
        local_topk_indices(s.T, 64, 8, axis=0)   # ragged rows via axis=0


def test_shard_buffer_model_stays_within_bound():
    """The modeled per-device compaction buffer respects the
    O(compact_factor * k / n_shards) bound for every shard count the CI
    matrix exercises (the acceptance invariant the benchmark records)."""
    for m, n, density in [(512, 512, 0.01), (512, 512, 0.05),
                          (256, 384, 0.2), (1024, 4096, 0.05)]:
        k = int(density * m * n)
        for n_shards in (1, 2, 4, 8):
            if n % n_shards:
                continue
            rec = kops.shard_buffer_model(m, n, k, n_shards)
            assert rec["within_bound"], (m, n, k, n_shards, rec)
            assert rec["buffer_slots_per_device"] * n_shards >= k
