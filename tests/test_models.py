"""Model zoo correctness: every family forward/backward, flash==naive,
decode==teacher-forced forward, and one reduced smoke test PER ASSIGNED
ARCHITECTURE (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.models import ModelConfig, build_model

BASE = dict(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
            head_dim=8, d_ff=64, vocab_size=97)


def _batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {"labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((B, S))}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = 0.1 * jax.random.normal(k, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    return batch


def _train_one(cfg, B=2, S=16):
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, B, S)
    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss)), cfg.name
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, cfg.name
    h, _ = m.forward(params, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h)))
    return m, params


# ------------------------------------------------- families (unit-level)
@pytest.mark.parametrize("opts", [
    dict(family="dense", qk_norm=True, qkv_bias=True),
    dict(family="dense", sliding_window=8),
    dict(family="dense", attn_chunk=4, loss_chunk=8),
    dict(family="moe", num_experts=4, num_experts_per_tok=2),
    dict(family="rwkv6", rwkv_head_dim=8, rwkv_decay_lora=8, rwkv_mix_lora=4),
    dict(family="encoder", causal=False, mlp_glu=False, mlp_act="gelu",
         input_mode="embeddings"),
    dict(family="hybrid", shared_attn_period=2, ssm_state=8, ssm_head_dim=8,
         ssm_chunk=4),
    dict(family="dense", tie_embeddings=True, scale_embeddings=True),
])
def test_family_train_step(opts):
    _train_one(ModelConfig(**BASE, **opts))


def test_flash_equals_naive_attention():
    cfg_n = ModelConfig(**BASE)
    cfg_f = cfg_n.replace(attn_chunk=4)
    m, mf = build_model(cfg_n), build_model(cfg_f)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg_n)
    l1, l2 = m.loss(params, batch)[0], mf.loss(params, batch)[0]
    assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: mf.loss(p, batch)[0])(params)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-4


@pytest.mark.parametrize("opts,cache_len", [
    (dict(family="dense"), 32),
    (dict(family="dense", sliding_window=8), 8),   # rolling buffer
    (dict(family="moe", num_experts=4, num_experts_per_tok=2,
          capacity_factor=8.0), 32),
    (dict(family="rwkv6", rwkv_head_dim=8, rwkv_decay_lora=8,
          rwkv_mix_lora=4), 32),
    (dict(family="hybrid", shared_attn_period=2, ssm_state=8,
          ssm_head_dim=8, ssm_chunk=4), 32),
])
def test_decode_matches_teacher_forcing(opts, cache_len):
    cfg = ModelConfig(**BASE, **opts)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 97)
    full = m.logits(params, {"tokens": toks})
    cache = m.init_cache(B, cache_len)
    lg, cache = m.prefill(params, {"tokens": toks[:, :8]}, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, 7])))]
    for t in range(8, S):
        lg, cache = m.decode(params, toks[:, t:t + 1], cache,
                             jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 2e-3, (opts, errs)


def test_swa_rolling_buffer_decode_long():
    """Decode past the window: rolling cache must equal windowed attention."""
    cfg = ModelConfig(**BASE, sliding_window=6)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, 97)
    full = m.logits(params, {"tokens": toks})
    cache = m.init_cache(B, 6)        # buffer == window
    lg, cache = m.prefill(params, {"tokens": toks[:, :8]}, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, 7])))]
    for t in range(8, S):
        lg, cache = m.decode(params, toks[:, t:t + 1], cache,
                             jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 2e-3, errs


# --------------------------------------------- assigned-arch smoke tests
@pytest.mark.parametrize("arch", ASSIGNED + ["llama2-7b"])
def test_arch_smoke(arch):
    """Reduced config of the same family: one train step on CPU, output
    shapes + no NaNs (the FULL config is exercised via the dry-run)."""
    bundle = get_arch(arch)
    cfg = bundle.smoke
    m, params = _train_one(cfg, B=2, S=16)
    # serving smoke for decoder archs
    if not cfg.is_encoder:
        cache = m.init_cache(1, 24)
        pre = _batch(cfg, B=1, S=8)
        pre.pop("labels"), pre.pop("loss_mask")
        logits, cache = m.prefill(params, pre, cache)
        assert logits.shape[-1] == cfg.vocab_size
        tok = jnp.zeros((1, 1), jnp.int32)
        lg, cache = m.decode(params, tok, cache,
                             jnp.full((1,), 8, jnp.int32))
        assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The FULL configs must carry the exact assigned hyperparameters."""
    spec = {
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "moonshot-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "rwkv6-1.6b": (24, 2048, None, None, 7168, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    cfg = get_arch(arch).full
    L, d, h, kv, ff, vocab = spec
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.d_ff == ff and cfg.vocab_size == vocab
    if h is not None:
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    if arch == "moonshot-16b-a3b":
        assert cfg.num_experts == 64 and cfg.num_experts_per_tok == 6
    if arch == "mixtral-8x22b":
        assert cfg.num_experts == 8 and cfg.num_experts_per_tok == 2
        assert cfg.sliding_window == 4096
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_period == 6
    if arch == "qwen3-1.7b":
        assert cfg.qk_norm
    if arch.startswith("qwen2"):
        assert cfg.qkv_bias
    if arch == "gemma-7b":
        assert cfg.head_dim == 256 and cfg.mlp_act == "gelu"
