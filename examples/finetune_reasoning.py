"""End-to-end reasoning-SFT driver (paper §5 pipeline at reduced scale).

Trains a decoder LM on the synthetic arithmetic-reasoning corpus with LIFT
and with Full FT, evaluating exact-answer accuracy on held-out problems and
source-domain retention (commonsense) — the paper's learning-vs-forgetting
comparison (Fig. 4), end to end: data pipeline, LIFT mask refresh,
checkpointing, eval.

Default size is CPU-friendly; `--size 100m --steps 300` reproduces the
"~100M model, few hundred steps" configuration on real hardware.

    PYTHONPATH=src python examples/finetune_reasoning.py [--size 100m]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.data.loader import ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, eval_accuracy, generate
from repro.models import ModelConfig, build_model
from repro.training import trainer as T

SIZES = {
    "tiny": dict(num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
                 head_dim=24, d_ff=192),
    "20m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=6,
                head_dim=64, d_ff=1024),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 head_dim=64, d_ff=2048),
}


def run(size: str, method_kind: str, steps: int, batch: int, seq: int,
        lr: float, ckpt_dir: str = ""):
    cfg = ModelConfig(family="dense", vocab_size=max(97, VOCAB_SIZE),
                      **SIZES[size])
    model = build_model(cfg)
    method = T.MethodConfig(kind=method_kind, lift=LiftConfig(
        rank=16, density=0.05, method="randomized", min_dim=16,
        update_interval=50))
    params = model.init(jax.random.PRNGKey(0))
    engine = T.selection_engine(model, method)  # shared: init + refresh
    params, state = T.init_train_state(model, params, method,
                                       jax.random.PRNGKey(1), engine=engine)
    step_fn = jax.jit(T.make_train_step(
        model, method, sa.AdamConfig(lr=lr),
        T.warmup_linear(steps, 0.03, lr)))
    refresh = T.make_refresh_step(model, method, engine=engine) \
        if method_kind == "lift" else None

    loader = ShardedLoader(generate("arith", 4096, seq, seed=0),
                           batch_size=batch, seed=0)
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    t0 = time.time()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        params, state, metrics = step_fn(params, state, b)
        if refresh is not None and (i + 1) % 50 == 0:
            state = refresh(params, state, jax.random.PRNGKey(i))
        if i % 20 == 0:
            print(f"[{method_kind}] step {i:4d} "
                  f"loss {float(metrics['loss']):.4f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
        if ckpt is not None and (i + 1) % 100 == 0:
            ckpt.save_async(i + 1, {"params": params, "state": state},
                            meta={"loader": loader.state.to_dict()})
    if ckpt is not None:
        ckpt.wait()
    eff = T.effective_params(model, params, state, method)
    tgt = eval_accuracy(model, eff, "arith", n=48, seq_len=seq)
    src = eval_accuracy(model, eff, "common", n=48, seq_len=seq)
    print(f"[{method_kind}] target-domain acc {tgt:.3f}   "
          f"source-domain acc {src:.3f}")
    return tgt, src


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=40)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--methods", default="lift,full")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    results = {}
    for kind in args.methods.split(","):
        results[kind] = run(args.size, kind, args.steps, args.batch,
                            args.seq, args.lr, args.ckpt_dir)
    print("\n=== summary (target acc / source acc) ===")
    for kind, (tgt, src) in results.items():
        print(f"  {kind:6s}  {tgt:.3f} / {src:.3f}")
