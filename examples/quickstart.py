"""Quickstart: LIFT in ~40 lines.

Builds a small decoder LM, selects the Principal Weights (top-5 % magnitude
entries after rank-8 reduction), fine-tunes ONLY those with the sparse
AdamW, and shows that (a) the loss drops, (b) only ~5 % of entries moved,
(c) optimizer state is tiny.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.data.loader import ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, generate
from repro.models import ModelConfig, build_model
from repro.training import trainer as T

cfg = ModelConfig(family="dense", num_layers=2, d_model=96, num_heads=4,
                  num_kv_heads=2, head_dim=24, d_ff=192,
                  vocab_size=max(97, VOCAB_SIZE))
model = build_model(cfg)

method = T.MethodConfig(kind="lift", lift=LiftConfig(
    rank=8,           # LRA rank r: W' = SVD_r(W)
    density=0.05,     # keep the top-5% of |W'| -> Principal Weights
    method="exact", min_dim=16, update_interval=25))

params = model.init(jax.random.PRNGKey(0))
params0 = params
engine = T.selection_engine(model, method)  # shared: init + every refresh
params, state = T.init_train_state(model, params, method,
                                   jax.random.PRNGKey(1), engine=engine)
step = jax.jit(T.make_train_step(model, method, sa.AdamConfig(lr=2e-3),
                                 T.constant_lr(2e-3)))
refresh = T.make_refresh_step(model, method, engine=engine)

loader = ShardedLoader(generate("arith", 512, 40, seed=0), batch_size=16)
for i in range(50):
    batch = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
    params, state, metrics = step(params, state, batch)
    if (i + 1) % 25 == 0:
        state = refresh(params, state, jax.random.PRNGKey(i))
    if i % 10 == 0 or i == 49:
        print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")

changed = sum(int((np.asarray(a) != np.asarray(b)).sum())
              for a, b in zip(jax.tree.leaves(params0),
                              jax.tree.leaves(params)))
total = sum(x.size for x in jax.tree.leaves(params))
opt_bytes = sum(x.size * x.dtype.itemsize
                for x in jax.tree.leaves(state["opt"]))
full_opt_bytes = 8 * total
print(f"\nchanged {changed}/{total} params ({100 * changed / total:.2f}%)")
print(f"optimizer state {opt_bytes / 1e6:.2f} MB "
      f"(Full-FT AdamW would be {full_opt_bytes / 1e6:.2f} MB -> "
      f"{100 * opt_bytes / full_opt_bytes:.1f}%)")
