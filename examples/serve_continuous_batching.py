"""Continuous-batching serving demo in two acts (docs/SERVING.md).

Act 1 — one engine, two prefill modes: a stream of reasoning prompts
through the unified paged engine (`repro.serving.make_engine`) with
whole-prompt prefill vs chunked prefill, watching slot admission /
page accounting (DESIGN.md §5) — greedy token streams are identical.

Act 2 — merge-free multi-adapter serving: two LIFT-style sparse deltas
served from a paged adapter pool, MIXED per slot in one decode batch,
vs the merge-on-load AdapterStore reference (`--adapter-pool` vs plain
`--delta` in `launch/serve.py`) — token streams must match bitwise at
every temperature.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.data.synthetic import (BOS, EOS, SEP, VOCAB_SIZE, decode, encode,
                                  make_arith_example)
from repro.models import ModelConfig, build_model
from repro.serving import (AdapterStore, Request, ServingConfig,
                           make_engine)
from repro.serving.kvpool import AdapterPool

cfg = ModelConfig(family="dense", num_layers=2, d_model=96, num_heads=4,
                  num_kv_heads=2, head_dim=24, d_ff=192,
                  vocab_size=max(97, VOCAB_SIZE))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))


def requests(adapter_ids=(None,)):
    rng = np.random.default_rng(0)
    out = []
    for i in range(10):
        q, _ = make_arith_example(rng)
        out.append(Request(uid=i,
                           prompt=np.asarray([BOS] + encode(q) + [SEP]),
                           max_new_tokens=12,
                           # mixed temperatures on purpose: identity
                           # claims hold for sampled requests too
                           temperature=0.0 if i % 2 == 0 else 0.8,
                           adapter_id=adapter_ids[i % len(adapter_ids)]))
    return out


def drive(engine, label, adapter_ids=(None,)):
    """Run the stream; on paged engines also track the PEAK number of
    distinct adapters decoding in one batch step."""
    for r in requests(adapter_ids):
        engine.submit(r)
    mixed = 0
    t0 = time.time()
    if hasattr(engine, "sched"):
        while engine.sched.has_work():
            engine.step()
            live = {s.req.adapter_id for s in engine.sched.seqs
                    if s is not None and s.phase == "decode"
                    and s.req.adapter_id is not None}
            mixed = max(mixed, len(live))
        done = engine.done
    else:
        done = engine.run()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    note = f", peak {mixed} adapters in one batch" if mixed else ""
    print(f"[{label}] {len(done)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s{note})")
    return {r.uid: tuple(r.out_tokens) for r in done}


# ------------------- act 1: whole-prompt vs chunked prefill, ONE engine
dense = drive(make_engine(model, params,
                          ServingConfig(batch_slots=4, max_len=96,
                                        eos_id=EOS, page_size=16,
                                        num_pages=32)),
              "whole-prompt prefill, 4 slots")

paged_eng = make_engine(model, params, ServingConfig(
    batch_slots=4, max_len=96, eos_id=EOS, page_size=16, num_pages=32,
    chunked_prefill=True, prefill_chunk=16))
paged = drive(paged_eng, "chunked prefill")

st = paged_eng.kv_stats()
# greedy streams are guaranteed identical under chunked prefill; the
# sampled (temperature 0.8) requests additionally match whenever the
# chunked logits agree to sampling precision, as they do here
greedy_same = all(dense[r.uid] == paged[r.uid]
                  for r in requests() if r.temperature == 0.0)
print(f"\ngreedy token streams identical: {greedy_same} (guaranteed); "
      f"all streams identical: {dense == paged}")
print(f"peak KV residency: {st['peak_pages_in_use']}/{st['num_pages']} "
      f"pages = {st['peak_kv_bytes'] / 1e3:.0f} kB, "
      f"{st['kv_bytes_ratio']:.2f}x the dense slots x max_len cache "
      f"({st['peak_live_tokens']} live tokens at peak)")
for r_uid in range(3):
    print(f"req {r_uid}: {decode(list(paged[r_uid]))!r}")


# ------------------- act 2: merge-free adapter mixing in ONE batch
# Two synthetic LIFT fine-tunes: mode="replace" artifacts perturbing the
# base at 5%-density principal-weight positions (the geometry of a real
# `deltas.extract`, without the training run — docs/SERVING.md walks
# the real train -> extract -> ship -> serve workflow).
from repro.core.lift import LiftConfig, get_by_path, make_plan
from repro.deltas import DeltaArtifact, tree_hash
from repro.deltas.format import make_manifest, num_stack

plan = make_plan(model.spec(), LiftConfig(density=0.05, min_dim=16))
meta = {p: {"shape": list(t.shape), "stack": list(t.stack), "rows": t.rows,
            "cols": t.cols, "k": t.k, "dtype": "float32"}
        for p, t in sorted(plan.items())}
base_hash = tree_hash(params)


def synthetic_adapter(seed):
    rng = np.random.default_rng(seed)
    tensors = {}
    for path, m in meta.items():
        ns, k, size = num_stack(m), m["k"], m["rows"] * m["cols"]
        idx = np.stack([np.sort(rng.choice(size, k, replace=False))
                        for _ in range(ns)]).astype(np.int32)
        base = np.asarray(get_by_path(params, path),
                          np.float32).reshape(ns, size)
        val = (np.take_along_axis(base, idx, 1)
               + rng.normal(scale=0.05, size=(ns, k))).astype(np.float32)
        tensors[path] = {"idx": idx, "val": val}
    return DeltaArtifact(
        manifest=make_manifest(mode="replace", base_hash=base_hash,
                               selection=None, tensors_meta=meta, step=0),
        tensors=tensors)


arts = {"alice": synthetic_adapter(1), "bob": synthetic_adapter(2)}
pcfg = dict(batch_slots=4, max_len=96, eos_id=EOS, page_size=16,
            num_pages=32)

# reference path: merge-on-load — each adapter costs a full merged copy
# of the weights, and slots batch per adapter (tree swaps between)
store = AdapterStore(params)
for aid, art in arts.items():
    store.load(aid, art)
ref_eng = make_engine(model, params, ServingConfig(**pcfg),
                      adapters=store)

# merge-free path: ONE base weight set + a paged (idx, val) pool; each
# slot's delta composes into the forward matmuls, so one decode batch
# serves alice, bob and the bare base simultaneously.  Size the pool
# for the working set (1 trash page + pages_per_adapter per resident
# adapter — the launcher prints pages/adapter at registration); an
# undersized pool stays CORRECT but thrashes uploads/evictions as
# slots take turns instead of mixing
apool = AdapterPool(params, num_pages=40, entries_per_page=512)
for aid, art in arts.items():
    apool.register(aid, art)
pool_eng = make_engine(model, params, ServingConfig(**pcfg),
                       adapter_pool=apool)

mix = ("alice", "bob", None)   # None = the unadapted base model
print(f"\n--- merge-free adapter pool: serving {list(arts)} + base, "
      f"mixed per slot ---")
want = drive(ref_eng, "merge-on-load AdapterStore (reference)", mix)
got = drive(pool_eng, "merge-free adapter pool", mix)

ps = pool_eng.pool_stats()
print(f"\npool streams bitwise-identical to merge-on-load "
      f"(all temperatures): {got == want}")
print(f"adapter pool: {ps['resident_adapters']} adapters resident in "
      f"{ps['pages_per_adapter']} page(s) each, "
      f"{100 * ps['adapter_bytes_ratio']:.1f}% of one dense merged copy "
      f"per adapter ({ps['uploads']} uploads, "
      f"{ps['evictions']} evictions)")
for uid, aid in zip(range(3), mix):
    print(f"req {uid} [{aid or 'base'}]: {decode(list(got[uid]))!r}")
