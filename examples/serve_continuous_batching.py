"""Continuous-batching serving demo, dense cache vs PagedKV pool: submit
a stream of reasoning prompts, watch slot admission / chunked prefill /
page accounting, report tokens/s and KV residency.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.data.synthetic import (BOS, EOS, SEP, VOCAB_SIZE, decode, encode,
                                  make_arith_example)
from repro.models import ModelConfig, build_model
from repro.serving.engine import Engine, EngineConfig, Request
from repro.serving.kvpool import PagedEngine, PagedEngineConfig

cfg = ModelConfig(family="dense", num_layers=2, d_model=96, num_heads=4,
                  num_kv_heads=2, head_dim=24, d_ff=192,
                  vocab_size=max(97, VOCAB_SIZE))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))


def requests():
    rng = np.random.default_rng(0)
    out = []
    for i in range(10):
        q, _ = make_arith_example(rng)
        out.append(Request(uid=i,
                           prompt=np.asarray([BOS] + encode(q) + [SEP]),
                           max_new_tokens=12,
                           temperature=0.0 if i % 2 == 0 else 0.8))
    return out


def drive(engine, label):
    for r in requests():
        engine.submit(r)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"[{label}] {len(done)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s)")
    return {r.uid: tuple(r.out_tokens) for r in done}


dense = drive(Engine(model, params,
                     EngineConfig(batch_slots=4, max_len=96, eos_id=EOS)),
              "dense cache, 4 slots")

paged_eng = PagedEngine(model, params, PagedEngineConfig(
    batch_slots=4, max_len=96, eos_id=EOS, page_size=16, num_pages=32,
    chunked_prefill=True, prefill_chunk=16))
paged = drive(paged_eng, "paged pool, chunked prefill")

st = paged_eng.kv_stats()
# greedy streams are guaranteed identical under chunked prefill; the
# sampled (temperature 0.8) requests additionally match whenever the
# chunked logits agree to sampling precision, as they do here
greedy_same = all(dense[r.uid] == paged[r.uid]
                  for r in requests() if r.temperature == 0.0)
print(f"\ngreedy token streams identical: {greedy_same} (guaranteed); "
      f"all streams identical: {dense == paged}")
print(f"peak KV residency: {st['peak_pages_in_use']}/{st['num_pages']} "
      f"pages = {st['peak_kv_bytes'] / 1e3:.0f} kB, "
      f"{st['kv_bytes_ratio']:.2f}x the dense slots x max_len cache "
      f"({st['peak_live_tokens']} live tokens at peak)")
for r_uid in range(3):
    print(f"req {r_uid}: {decode(list(paged[r_uid]))!r}")
