"""Continuous-batching serving demo: submit a stream of reasoning prompts,
watch slot admission / eviction, report tokens/s.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.data.synthetic import (BOS, EOS, SEP, VOCAB_SIZE, decode, encode,
                                  make_arith_example)
from repro.models import ModelConfig, build_model
from repro.serving.engine import Engine, EngineConfig, Request

cfg = ModelConfig(family="dense", num_layers=2, d_model=96, num_heads=4,
                  num_kv_heads=2, head_dim=24, d_ff=192,
                  vocab_size=max(97, VOCAB_SIZE))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = Engine(model, params,
                EngineConfig(batch_slots=4, max_len=96, eos_id=EOS))
rng = np.random.default_rng(0)
for i in range(10):
    q, _ = make_arith_example(rng)
    engine.submit(Request(uid=i,
                          prompt=np.asarray([BOS] + encode(q) + [SEP]),
                          max_new_tokens=12,
                          temperature=0.0 if i % 2 == 0 else 0.8))

t0 = time.time()
done = engine.run()
dt = time.time() - t0
tokens = sum(len(r.out_tokens) for r in done)
for r in sorted(done, key=lambda r: r.uid)[:5]:
    print(f"req {r.uid}: {decode(r.out_tokens)!r}")
print(f"\n{len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens / dt:.1f} tok/s with 4-slot continuous batching)")
