"""Paper §4 (Fig. 2): Principal Weights are the fragile ones.

Trains a small LM, then adds N(0, sigma^2) noise to (a) LIFT-selected,
(b) largest-magnitude, (c) random parameter sets of equal size and reports
the loss blow-up.  LIFT's selections should be dramatically more sensitive.

    PYTHONPATH=src python examples/perturbation_analysis.py
"""
import jax
import jax.numpy as jnp

from repro.core.analysis import perturb_at_indices
from repro.core.lift import LiftConfig, compute_indices, make_plan
from repro.core import sparse_adam as sa
from repro.data.loader import ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, generate
from repro.models import ModelConfig, build_model
from repro.training import trainer as T

cfg = ModelConfig(family="dense", num_layers=2, d_model=96, num_heads=4,
                  num_kv_heads=2, head_dim=24, d_ff=192,
                  vocab_size=max(97, VOCAB_SIZE))
model = build_model(cfg)

# quick LM pre-training so the weights carry structure
method = T.MethodConfig(kind="full")
params = model.init(jax.random.PRNGKey(0))
params, state = T.init_train_state(model, params, method,
                                   jax.random.PRNGKey(1))
step = jax.jit(T.make_train_step(model, method, sa.AdamConfig(lr=2e-3),
                                 T.constant_lr(2e-3)))
loader = ShardedLoader(generate("lm", 512, 40, seed=0), batch_size=16)
for _ in range(60):
    b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
    params, state, _ = step(params, state, b)

batch = {k: jnp.asarray(v) for k, v in
         generate("lm", 128, 40, seed=99).items()}
base = float(model.loss(params, batch)[0])
print(f"clean loss {base:.4f}\n")
print(f"{'selection':<12}" + "".join(f"sigma={s:<8}" for s in
                                     (0.01, 0.02, 0.05)))
for sel in ["lift", "magnitude", "random"]:
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", selection=sel,
                      min_dim=16)
    plan = make_plan(model.spec(), lcfg)
    idx = compute_indices(params, plan, lcfg, jax.random.PRNGKey(3))
    row = []
    for scale in (0.01, 0.02, 0.05):
        pert = perturb_at_indices(params, idx, plan, scale,
                                  jax.random.PRNGKey(7))
        row.append(float(model.loss(pert, batch)[0]) - base)
    print(f"{sel:<12}" + "".join(f"+{d:<13.4f}"[:14] for d in row))
print("\n(larger = more damage; LIFT-selected Principal Weights should "
      "dominate, paper Fig. 2)")
