"""Elastic restart demo: train on a 4-device (2x2) mesh, checkpoint, crash,
then resume on an 8-device (4x2) mesh — the checkpoint stores logical
arrays, so the restore re-shards onto whatever topology the restarted job
has (DESIGN.md §6).  Runs each phase in a subprocess with a different
--xla_force_host_platform_device_count.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import subprocess
import sys
import tempfile

PHASE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import sys, json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.data.loader import LoaderState, ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, generate
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig, build_model
from repro.parallel.sharding import set_sharding_ctx, tree_shardings
from repro.training import trainer as T

ndev = %(ndev)d
mesh = make_host_mesh(%(dp)d, %(tp)d)
set_sharding_ctx(mesh)
cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=4, head_dim=16, d_ff=128,
                  vocab_size=128)  # divisible by every test mesh axis
model = build_model(cfg)
method = T.MethodConfig(kind="lift", lift=LiftConfig(
    rank=4, match_rank=1, method="exact", min_dim=16, k_multiple=8))
params = model.init(jax.random.PRNGKey(0))
params, state = T.init_train_state(model, params, method,
                                   jax.random.PRNGKey(1))
step = jax.jit(T.make_train_step(model, method, sa.AdamConfig(lr=1e-3),
                                 T.constant_lr(1e-3)))
loader = ShardedLoader(generate("arith", 128, 32, seed=0), batch_size=8)
ckpt = CheckpointManager(%(ckpt)r, keep=3)
start = 0
latest = ckpt.latest_step()
if latest is not None:
    sh = tree_shardings(model.axes(), mesh)
    r = ckpt.restore(latest, {"params": params, "state": state})
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, s), r["params"], sh)
    state = r["state"]
    loader.state = LoaderState.from_dict(ckpt.restore_meta(latest)["loader"])
    start = latest
    print(f"[{ndev}dev] resumed from step {latest}; params resharded onto "
          f"mesh {mesh.devices.shape}")
for i in range(start, %(steps)d):
    b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
    params, state, metrics = step(params, state, b)
    if (i + 1) %% 4 == 0:
        ckpt.save(i + 1, {"params": params, "state": state},
                  meta={"loader": loader.state.to_dict()})
print(f"[{ndev}dev] finished at step {%(steps)d} "
      f"loss={float(metrics['loss']):.4f}")
import numpy as np
np.save(%(out)r, np.asarray(jax.tree.leaves(params)[0], np.float32))
"""


def run_phase(ndev, dp, tp, ckpt, steps, out):
    code = PHASE % dict(ndev=ndev, dp=dp, tp=tp, ckpt=ckpt, steps=steps,
                        out=out)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True)
    print(r.stdout, end="")
    if r.returncode != 0:
        print(r.stderr[-2000:])
        raise SystemExit("phase failed")


if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "ckpt")
        a, b = os.path.join(td, "a.npy"), os.path.join(td, "b.npy")
        print("phase 1: 4 devices (2x2), train to step 8, checkpointing")
        run_phase(4, 2, 2, ck, 8, a)
        print("phase 2: 8 devices (4x2), resume from the same checkpoint")
        run_phase(8, 4, 2, ck, 12, b)
        print("phase 3: 1 device, resume again (scale DOWN)")
        run_phase(1, 1, 1, ck, 14, os.path.join(td, "c.npy"))
        print("\nelastic restart OK: one checkpoint, three topologies")
